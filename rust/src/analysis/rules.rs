//! The `pallas-lint` rule set: determinism & invariant rules D001–D011.
//!
//! Rules D001–D007 are lexical — they pattern-match the token stream
//! produced by [`crate::analysis::scanner`] — so rule text inside
//! strings, raw strings, chars, and comments can never fire. D008/D009
//! are *structural*: they walk the brace-matched item tree from
//! [`crate::analysis::structure`] and the unit environment from
//! [`crate::analysis::units`]. D010 is a docs-drift check run once per
//! sweep against `docs/STATIC_ANALYSIS.md`.
//!
//! Each diagnostic carries a machine-readable rule id, an exact 1-based
//! line, and an `allowed` flag, and can be suppressed by an inline
//! annotation **with a mandatory reason**. One comment may allow several
//! rule ids at once:
//!
//! ```text
//! // pallas-lint: allow(D004, D008, reason = "documented invariant")
//! // pallas-lint: allow-item(D009, reason = "slab ids are dense by construction")
//! ```
//!
//! A plain `allow` covers its own line and the next; an `allow-item`
//! attaches to the item (fn/impl/mod/…) whose attributes or header start
//! on the next line and covers that item's whole span. A reason-less,
//! unknown-rule, or otherwise malformed annotation is itself a
//! diagnostic (A000), an `allow-item` that attaches to nothing is A000,
//! and staleness (A001) is accounted **per rule id** — an
//! `allow(D004, D008)` where only D004 fires is stale for D008. The
//! sweep stays allowlist-exact: suppressed diagnostics are retained with
//! `allowed = true` (the JSON stream emits them; `--deny` ignores them).
//!
//! See `docs/STATIC_ANALYSIS.md` for the rule catalog, the unit-suffix
//! table, and the rationale tying each rule to the repo's
//! bit-exact-replay invariant.

use std::collections::BTreeSet;

use crate::analysis::scanner::{Scan, TokKind, Token};
use crate::analysis::structure::{self, Item, ItemKind};
use crate::analysis::units::{self, UnitsRules};

/// A single lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Machine-readable rule id (`D001`..`D011`, `A000`, `A001`).
    pub rule: &'static str,
    /// Repo-relative path of the offending file.
    pub file: String,
    /// 1-based line of the offending token.
    pub line: u32,
    /// Human explanation.
    pub message: String,
    /// True when an allow annotation suppresses this finding. Allowed
    /// diagnostics are retained (and serialized) but never fail `--deny`.
    pub allowed: bool,
}

impl Diagnostic {
    /// One JSONL record: a single-line JSON object with the keys
    /// `allowed`, `file`, `line`, `message`, `rule` (alphabetical — the
    /// writer sorts keys, so the stream is byte-stable).
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("allowed".to_string(), Json::Bool(self.allowed));
        obj.insert("file".to_string(), Json::Str(self.file.clone()));
        obj.insert("line".to_string(), Json::I64(i64::from(self.line)));
        obj.insert("message".to_string(), Json::Str(self.message.clone()));
        obj.insert("rule".to_string(), Json::Str(self.rule.to_string()));
        Json::Obj(obj).to_string()
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)?;
        if self.allowed {
            write!(f, " (allowed)")?;
        }
        Ok(())
    }
}

/// Catalog entry for one rule (the `lint --rules` listing, the
/// `lint --explain` text, and the docs table are all tied to this one
/// table — D010 checks the docs side).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Machine-readable id.
    pub id: &'static str,
    /// One-line summary.
    pub summary: &'static str,
    /// Where the rule applies.
    pub scope: &'static str,
    /// Longer rationale shown by `lint --explain <ID>`.
    pub explain: &'static str,
}

/// The rule catalog, in id order (A-rules sort before D-rules).
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "A000",
        summary: "malformed pallas-lint annotation (unknown rule, duplicate rule id, \
                  missing or empty reason, or an allow-item that attaches to no item)",
        scope: "everywhere (engine-generated; not allowable)",
        explain: "Suppressions are part of the reviewed surface: an annotation that \
                  fails to parse, names an unknown or duplicate rule, omits its reason, \
                  or (for allow-item) does not sit directly above an item's attributes \
                  or header is itself an error — never a silent no-op.",
    },
    RuleInfo {
        id: "A001",
        summary: "stale allow annotation: a listed rule id suppresses no diagnostic",
        scope: "everywhere (engine-generated; not allowable)",
        explain: "Every allowed rule id must pay rent. When the code it excused is \
                  fixed or deleted, the annotation (or the one id within a multi-id \
                  annotation) must be removed, keeping the allowlist exact.",
    },
    RuleInfo {
        id: "D001",
        summary: "no HashMap/HashSet iteration (iter/keys/values/drain/retain/for-in); \
                  iteration order is nondeterministic and breaks bit-exact replay",
        scope: "rust/src/coordinator, rust/src/cluster, rust/src/bench",
        explain: "The simulator's headline invariant is bit-exact replay: the same seed \
                  must produce the same event stream, trace, and report on every run. \
                  std's hash collections randomize iteration order per process, so any \
                  iteration that can reach an ordered artifact silently breaks replay. \
                  Point lookups (get/insert/remove/entry) are fine. Use BTreeMap/BTreeSet \
                  or a slab with dense indices when order matters.",
    },
    RuleInfo {
        id: "D002",
        summary: "no partial_cmp calls on floats; f64::total_cmp is the repo rule (NaN-safe, \
                  total order) since PR 5",
        scope: "everywhere",
        explain: "partial_cmp returns None for NaN, which either panics through the \
                  customary .unwrap() or silently mis-sorts, and either way makes float \
                  ordering depend on data. f64::total_cmp is total and NaN-safe, and the \
                  whole tree was moved onto it in PR 5. Defining partial_cmp in a \
                  PartialOrd impl is fine; calling it is not.",
    },
    RuleInfo {
        id: "D003",
        summary: "no Instant::now/SystemTime::now on simulation paths; wall-clock reads are \
                  confined to the bench harness",
        scope: "everywhere except rust/src/util/benchkit.rs and rust/benches",
        explain: "Simulated time comes from the discrete-event clock; a wall-clock read \
                  on a simulation path couples results to host timing and destroys \
                  reproducibility. Real-time measurement belongs to util/benchkit.rs and \
                  benches/, which exist for exactly that purpose.",
    },
    RuleInfo {
        id: "D004",
        summary: "no unwrap()/expect() in coordinator non-test code without a reviewed reason",
        scope: "rust/src/coordinator, outside #[cfg(test)]/#[test] items",
        explain: "The coordinator is the long-running control loop: a panic there takes \
                  down the whole simulated fleet. Fallible lookups must return typed \
                  errors or be annotated with an allow(D004) stating the invariant that \
                  makes the unwrap infallible. Tests are exempt — panicking is how tests \
                  fail.",
    },
    RuleInfo {
        id: "D005",
        summary: "no corrupted doc-comment markers (`/!`, or a lone `/ ` before prose); \
                  rustdoc drops such lines silently",
        scope: "everywhere (code context only; strings/comments exempt)",
        explain: "A doc comment that lost a slash (`/! …` or `/ Prose…`) parses as a \
                  division or path fragment, so rustdoc drops the line without a warning \
                  and reviewers read docs that the toolchain never sees. The rule \
                  pattern-matches the two known corruption shapes at line starts in code \
                  context; line-wrapped real division continues with lowercase/digits and \
                  never matches.",
    },
    RuleInfo {
        id: "D006",
        summary: "crate roots carry #![forbid(unsafe_code)] and no unsafe token appears",
        scope: "attribute: rust/src/lib.rs + rust/src/main.rs; token ban: everywhere",
        explain: "The crate is pure-safe Rust by policy; #![forbid(unsafe_code)] makes \
                  the compiler enforce it and the token ban catches stray unsafe in \
                  files that bypass the root (build scripts, examples).",
    },
    RuleInfo {
        id: "D007",
        summary: "no concurrency primitives (std::thread, std::sync::mpsc, Mutex/RwLock/\
                  Condvar, atomics) outside the conservative parallel engine; \
                  nondeterministic interleaving must never leak into engine code",
        scope: "everywhere except rust/src/coordinator/parallel.rs and rust/src/util/benchkit.rs",
        explain: "PR 8's parallel engine is pinned byte-exact against the single-threaded \
                  loop precisely because all cross-thread communication is confined to \
                  one reviewed file with a conservative synchronization window. A thread, \
                  channel, lock, or atomic anywhere else would reintroduce scheduling \
                  nondeterminism the pinning can't see.",
    },
    RuleInfo {
        id: "D008",
        summary: "no +/-/comparison between identifiers carrying different unit suffixes \
                  (_us, _ms, _cycles, _uj, _mw, _rps, _bytes, _bits, _len/_depth); \
                  convert through a named *_to_* fn",
        scope: "every non-test fn, tree-wide",
        explain: "The codebase carries physical dimensions in identifier suffixes and \
                  has already shipped one unit bug (a *_bits helper that returned \
                  bytes). D008 infers a unit per identifier from its suffix, propagates \
                  through simple let bindings, and flags additive or comparison \
                  operators whose operands carry different known units. Multiplication \
                  and division are exempt (count * cycles is cycles), unknown units \
                  never fire, and a call through a *_to_<unit> conversion fn is trusted \
                  to produce its named unit.",
    },
    RuleInfo {
        id: "D009",
        summary: "panic surface on coordinator non-test paths: panic-family macros and \
                  unchecked indexing/slicing need an annotated invariant",
        scope: "rust/src/coordinator, outside #[cfg(test)]/#[test] items",
        explain: "D004 covers unwrap/expect; D009 audits the rest of the panic surface \
                  on the same no-panic paths: panic!/unreachable!/todo!/unimplemented!/\
                  assert! family macros, and `[...]` indexing or slicing of anything \
                  that can be out of bounds. Literal indices into fixed arrays, full-\
                  range `[..]` slices, and debug_assert* are exempt. Sites that are \
                  provably in bounds carry an allow(D009)/allow-item(D009) whose reason \
                  states the invariant.",
    },
    RuleInfo {
        id: "D010",
        summary: "rule catalog and docs/STATIC_ANALYSIS.md table must agree: every rule \
                  id has a docs row and every docs row names a registered rule",
        scope: "sweep-level (checked once per lint run against the docs file)",
        explain: "The rule table in docs/STATIC_ANALYSIS.md is the human contract for \
                  this linter. D010 diffs it against the registered RULES in both \
                  directions, so adding a rule without documenting it — or documenting \
                  a rule that no longer exists — fails the sweep.",
    },
    RuleInfo {
        id: "D011",
        summary: "fault-injection entropy confined to coordinator/faults.rs: no Rng on \
                  coordinator recovery/retry paths (request.rs workload generators exempt)",
        scope: "rust/src/coordinator, outside #[cfg(test)]/#[test] items; faults.rs and \
                request.rs exempt",
        explain: "Fault-mode runs must stay bit-replayable: every crash, recovery, \
                  straggler episode and outage window comes from the seeded FaultPlan \
                  streams in coordinator/faults.rs, and retry backoff is a closed-form \
                  deterministic schedule (RetryPolicy::backoff_us — no jitter). An Rng \
                  anywhere else in the coordinator could smuggle fresh entropy into a \
                  recovery decision, so the `Rng` ident itself is the tripwire. \
                  request.rs is exempt (arrival-shape entropy, seeded per workload); \
                  property-test fleet-shape helpers carry an allow-item naming why.",
    },
];

/// True for rule ids that may appear in an allow annotation.
pub fn is_known_rule(id: &str) -> bool {
    matches!(
        id,
        "D001"
            | "D002"
            | "D003"
            | "D004"
            | "D005"
            | "D006"
            | "D007"
            | "D008"
            | "D009"
            | "D010"
            | "D011"
    )
}

/// Lint one file's source text. `path` must be repo-relative with `/`
/// separators — rule scoping matches on it textually.
pub fn lint_file(path: &str, text: &str) -> Vec<Diagnostic> {
    let scan = crate::analysis::scanner::scan(text);
    let items = structure::build(&scan);
    let mut raw: Vec<Diagnostic> = Vec::new();
    d001_hash_iteration(path, &scan, &mut raw);
    d002_partial_cmp(path, &scan, &mut raw);
    d003_wall_clock(path, &scan, &mut raw);
    d004_unwrap_in_coordinator(path, &scan, &items, &mut raw);
    d005_corrupted_doc_markers(path, text, &scan, &mut raw);
    d006_unsafe(path, &scan, &mut raw);
    d007_concurrency(path, &scan, &mut raw);
    d011_fault_entropy(path, &scan, &items, &mut raw);
    let units_rules = UnitsRules {
        d008: true,
        d009: path.starts_with("rust/src/coordinator/"),
    };
    for (rule, line, message) in units::fn_units_pass(&scan, &items, units_rules) {
        raw.push(Diagnostic { rule, file: path.to_string(), line, message, allowed: false });
    }

    // resolve each allow to its covered line span: a plain allow covers
    // its own line and the next; an allow-item attaches to the item
    // whose attributes or header start on the next line and covers the
    // item's whole span (let bindings are not annotation targets)
    let mut flat: Vec<&Item> = Vec::new();
    structure::walk(&items, &mut |it| flat.push(it));
    let mut spans: Vec<Option<(u32, u32)>> = Vec::with_capacity(scan.allows.len());
    let mut attach_failed: Vec<u32> = Vec::new();
    for a in &scan.allows {
        if a.item_scoped {
            let target = flat.iter().find(|it| {
                it.kind != ItemKind::Let && (a.line + 1 == it.attr_line || a.line + 1 == it.line)
            });
            match target {
                Some(it) => spans.push(Some((it.attr_line, it.end_line))),
                None => {
                    attach_failed.push(a.line);
                    spans.push(None);
                }
            }
        } else {
            spans.push(Some((a.line, a.line + 1)));
        }
    }
    // staleness is accounted per (annotation, rule id)
    let mut used: Vec<Vec<bool>> =
        scan.allows.iter().map(|a| vec![false; a.rules.len()]).collect();
    let mut out: Vec<Diagnostic> = Vec::new();
    for mut d in raw {
        for (ai, a) in scan.allows.iter().enumerate() {
            let Some((lo, hi)) = spans[ai] else { continue };
            if lo <= d.line && d.line <= hi {
                for (ri, r) in a.rules.iter().enumerate() {
                    if r == d.rule {
                        used[ai][ri] = true;
                        d.allowed = true;
                    }
                }
            }
        }
        out.push(d);
    }
    for (line, why) in &scan.malformed {
        out.push(Diagnostic {
            rule: "A000",
            file: path.to_string(),
            line: *line,
            message: format!("malformed pallas-lint annotation: {why}"),
            allowed: false,
        });
    }
    for line in attach_failed {
        out.push(Diagnostic {
            rule: "A000",
            file: path.to_string(),
            line,
            message: "allow-item attaches to no item — place it directly above the \
                      item's attributes or header"
                .to_string(),
            allowed: false,
        });
    }
    for (ai, a) in scan.allows.iter().enumerate() {
        if spans[ai].is_none() {
            continue;
        }
        for (ri, r) in a.rules.iter().enumerate() {
            if !used[ai][ri] {
                out.push(Diagnostic {
                    rule: "A001",
                    file: path.to_string(),
                    line: a.line,
                    message: format!(
                        "stale allow({}) suppresses nothing — remove it (reason was: \"{}\")",
                        r, a.reason
                    ),
                    allowed: false,
                });
            }
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// D010: diff the registered rule catalog against the rule table in
/// `docs/STATIC_ANALYSIS.md` (both directions). A docs row is a line
/// starting with `|` whose first cell, stripped of backticks, is a
/// 4-char rule id; mentions in prose or code fences never count.
pub fn d010_docs_drift(docs_text: &str) -> Vec<Diagnostic> {
    const DOCS_FILE: &str = "docs/STATIC_ANALYSIS.md";
    let mut doc_ids: Vec<(String, u32)> = Vec::new();
    for (idx, line) in docs_text.lines().enumerate() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix('|') else { continue };
        let cell = rest.split('|').next().unwrap_or("").trim().trim_matches('`').trim();
        let id_shaped = cell.len() == 4
            && (cell.starts_with('D') || cell.starts_with('A'))
            && cell[1..].bytes().all(|b| b.is_ascii_digit());
        if id_shaped && !doc_ids.iter().any(|(c, _)| c == cell) {
            doc_ids.push((cell.to_string(), (idx + 1) as u32));
        }
    }
    let mut out = Vec::new();
    for r in RULES {
        if !doc_ids.iter().any(|(c, _)| c == r.id) {
            out.push(Diagnostic {
                rule: "D010",
                file: DOCS_FILE.to_string(),
                line: 1,
                message: format!("rule {} has no row in the docs catalog table", r.id),
                allowed: false,
            });
        }
    }
    for (cell, line) in &doc_ids {
        if !RULES.iter().any(|r| r.id == cell) {
            out.push(Diagnostic {
                rule: "D010",
                file: DOCS_FILE.to_string(),
                line: *line,
                message: format!("docs catalog row {cell} names no registered rule"),
                allowed: false,
            });
        }
    }
    out.sort_by(|a, b| (a.line, a.message.clone()).cmp(&(b.line, b.message.clone())));
    out
}

fn diag(out: &mut Vec<Diagnostic>, rule: &'static str, path: &str, line: u32, message: String) {
    out.push(Diagnostic { rule, file: path.to_string(), line, message, allowed: false });
}

fn is_punct(t: &Token, c: char) -> bool {
    t.kind == TokKind::Punct && t.text.len() == 1 && t.text.as_bytes()[0] == c as u8
}

fn is_ident(t: &Token, name: &str) -> bool {
    t.kind == TokKind::Ident && t.text == name
}

// ---------------------------------------------------------------- D001

const D001_DIRS: &[&str] = &["rust/src/coordinator/", "rust/src/cluster/", "rust/src/bench/"];

const D001_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
    "retain_mut",
    "extract_if",
];

/// Names in this file declared (or assigned) with a `HashMap`/`HashSet`
/// type: `name: …HashMap<…>` struct fields and `let` bindings, plus
/// `name = HashMap::new()` assignments. Lexical, per-file — aliases that
/// launder a hash map through another binding are out of scope (see
/// docs/STATIC_ANALYSIS.md, "Known limits").
fn hash_typed_names(toks: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        if !(is_ident(&toks[i], "HashMap") || is_ident(&toks[i], "HashSet")) {
            continue;
        }
        // walk back through type-position tokens to the declaring `:`
        // (or `=` for an inferred binding); give up fast on anything
        // that is not plausibly part of a type
        let mut j = i;
        let mut steps = 0;
        while j > 0 && steps < 32 {
            j -= 1;
            steps += 1;
            let t = &toks[j];
            if is_punct(t, ':') {
                if j > 0 && is_punct(&toks[j - 1], ':') {
                    j -= 1; // `::` path separator — keep walking
                    continue;
                }
                if j > 0 && toks[j - 1].kind == TokKind::Ident {
                    names.insert(toks[j - 1].text.clone());
                }
                break;
            }
            if is_punct(t, '=') {
                let arrow = j + 1 < toks.len() && is_punct(&toks[j + 1], '>');
                if !arrow && j > 0 && toks[j - 1].kind == TokKind::Ident {
                    names.insert(toks[j - 1].text.clone());
                }
                break;
            }
            let type_ish = t.kind == TokKind::Ident
                || t.kind == TokKind::Lifetime
                || is_punct(t, '<')
                || is_punct(t, '>')
                || is_punct(t, ',')
                || is_punct(t, '&')
                || is_punct(t, '(')
                || is_punct(t, ')');
            if !type_ish {
                break;
            }
        }
    }
    names
}

fn d001_hash_iteration(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    if !D001_DIRS.iter().any(|d| path.starts_with(d)) {
        return;
    }
    let toks = &scan.tokens;
    let names = hash_typed_names(toks);
    if names.is_empty() {
        return;
    }
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident || !names.contains(&toks[i].text) {
            continue;
        }
        // `name.iter()` / `self.name.drain(..)` and friends
        if i + 2 < toks.len()
            && is_punct(&toks[i + 1], '.')
            && toks[i + 2].kind == TokKind::Ident
            && D001_ITER_METHODS.contains(&toks[i + 2].text.as_str())
        {
            diag(
                out,
                "D001",
                path,
                toks[i + 2].line,
                format!(
                    "`{}.{}` iterates a hash collection — iteration order is \
                     nondeterministic; use BTreeMap/BTreeSet (or a slab/intrusive \
                     list) when order can reach a report, trace, or event stream",
                    toks[i].text, toks[i + 2].text
                ),
            );
        }
        // `for x in [&mut] [self.]name {`
        if i + 1 < toks.len() && is_punct(&toks[i + 1], '{') {
            let mut j = i;
            while j >= 2 && is_punct(&toks[j - 1], '.') && toks[j - 2].kind == TokKind::Ident {
                j -= 2;
            }
            while j >= 1 && (is_punct(&toks[j - 1], '&') || is_ident(&toks[j - 1], "mut")) {
                j -= 1;
            }
            if j >= 1 && is_ident(&toks[j - 1], "in") {
                diag(
                    out,
                    "D001",
                    path,
                    toks[i].line,
                    format!(
                        "`for … in {}` iterates a hash collection — iteration order \
                         is nondeterministic; use BTreeMap/BTreeSet instead",
                        toks[i].text
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- D002

fn d002_partial_cmp(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        if !is_ident(&toks[i], "partial_cmp") {
            continue;
        }
        let method_call = i >= 1 && is_punct(&toks[i - 1], '.');
        let path_ref = i >= 2 && is_punct(&toks[i - 1], ':') && is_punct(&toks[i - 2], ':');
        if method_call || path_ref {
            diag(
                out,
                "D002",
                path,
                toks[i].line,
                "`partial_cmp` is NaN-unsafe (returns None and panics downstream or \
                 silently mis-sorts); use `f64::total_cmp` — the repo rule since PR 5"
                    .to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------- D003

fn d003_wall_clock(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    if path == "rust/src/util/benchkit.rs" || path.starts_with("rust/benches/") {
        return;
    }
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        let clock = is_ident(&toks[i], "Instant") || is_ident(&toks[i], "SystemTime");
        if clock
            && i + 3 < toks.len()
            && is_punct(&toks[i + 1], ':')
            && is_punct(&toks[i + 2], ':')
            && is_ident(&toks[i + 3], "now")
        {
            diag(
                out,
                "D003",
                path,
                toks[i].line,
                format!(
                    "`{}::now` reads the wall clock — simulated time must come from \
                     the event clock; real-time reads live in util/benchkit.rs and \
                     benches/ (annotate genuine real-path measurements)",
                    toks[i].text
                ),
            );
        }
    }
}

// ---------------------------------------------------------------- D004

fn d004_unwrap_in_coordinator(path: &str, scan: &Scan, items: &[Item], out: &mut Vec<Diagnostic>) {
    if !path.starts_with("rust/src/coordinator/") {
        return;
    }
    let toks = &scan.tokens;
    let tests = structure::test_line_ranges(items);
    let in_test = |line: u32| tests.iter().any(|&(a, b)| a <= line && line <= b);
    for i in 1..toks.len() {
        let name = &toks[i];
        if name.kind != TokKind::Ident || (name.text != "unwrap" && name.text != "expect") {
            continue;
        }
        if !is_punct(&toks[i - 1], '.') || in_test(name.line) {
            continue;
        }
        diag(
            out,
            "D004",
            path,
            name.line,
            format!(
                "`.{}` in coordinator non-test code — return a typed error, or annotate \
                 the documented invariant with an allow(D004) reason",
                name.text
            ),
        );
    }
}

// ---------------------------------------------------------------- D005

/// A line whose first non-whitespace token looks like a doc-comment
/// marker that lost a slash: `/!`, or a lone `/` followed by a space and
/// an uppercase letter, `[`, or a backtick. Legitimate line-wrapped
/// divisions continue with lowercase identifiers, digits or `(`, so they
/// never match.
pub fn is_corrupted_marker(line: &str) -> bool {
    let t = line.trim_start();
    let Some(rest) = t.strip_prefix('/') else {
        return false;
    };
    if rest.starts_with('!') {
        return true;
    }
    match rest.strip_prefix(' ') {
        Some(after) => after.starts_with(|c: char| c.is_ascii_uppercase() || c == '[' || c == '`'),
        None => false,
    }
}

fn d005_corrupted_doc_markers(path: &str, text: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    for (idx, line) in text.lines().enumerate() {
        if scan.line_starts_in_code(idx + 1) && is_corrupted_marker(line) {
            diag(
                out,
                "D005",
                path,
                (idx + 1) as u32,
                format!(
                    "corrupted doc-comment marker (a `/` short of a doc comment — \
                     rustdoc drops the line silently): `{}`",
                    line.trim()
                ),
            );
        }
    }
}

// ---------------------------------------------------------------- D006

const D006_CRATE_ROOTS: &[&str] = &["rust/src/lib.rs", "rust/src/main.rs"];

fn d006_unsafe(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    let toks = &scan.tokens;
    for t in toks {
        if is_ident(t, "unsafe") {
            diag(
                out,
                "D006",
                path,
                t.line,
                "`unsafe` token — the crate forbids unsafe code (#![forbid(unsafe_code)])"
                    .to_string(),
            );
        }
    }
    if !D006_CRATE_ROOTS.contains(&path) {
        return;
    }
    let mut found = false;
    for i in 0..toks.len() {
        if is_punct(&toks[i], '#')
            && i + 7 < toks.len()
            && is_punct(&toks[i + 1], '!')
            && is_punct(&toks[i + 2], '[')
            && is_ident(&toks[i + 3], "forbid")
            && is_punct(&toks[i + 4], '(')
            && is_ident(&toks[i + 5], "unsafe_code")
            && is_punct(&toks[i + 6], ')')
            && is_punct(&toks[i + 7], ']')
        {
            found = true;
            break;
        }
    }
    if !found {
        diag(out, "D006", path, 1, "crate root is missing `#![forbid(unsafe_code)]`".to_string());
    }
}

// ---------------------------------------------------------------- D007

/// Files where concurrency primitives are reviewed and allowed: the
/// conservative parallel engine (whose determinism is pinned byte-exact
/// against the single-threaded loop) and the bench harness (real-time
/// measurement only, never simulation state).
const D007_ALLOWED_FILES: &[&str] =
    &["rust/src/coordinator/parallel.rs", "rust/src/util/benchkit.rs"];

/// Sync-primitive type names banned outside the allowed files.
const D007_TYPES: &[&str] = &["Mutex", "RwLock", "Condvar"];

fn d007_concurrency(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    if D007_ALLOWED_FILES.contains(&path) {
        return;
    }
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let sync_type = D007_TYPES.contains(&t.text.as_str());
        let atomic = t.text.starts_with("Atomic") && t.text.len() > "Atomic".len();
        // `thread::…` / `mpsc::…` path segments (spawn, scope, channel);
        // a bare `thread` binding or `.thread()` accessor never matches
        let path_seg = (t.text == "thread" || t.text == "mpsc")
            && i + 2 < toks.len()
            && is_punct(&toks[i + 1], ':')
            && is_punct(&toks[i + 2], ':');
        // `use std::sync::mpsc;` and `use std::thread;` imports
        let import = (t.text == "thread" || t.text == "mpsc")
            && i >= 2
            && is_punct(&toks[i - 1], ':')
            && is_punct(&toks[i - 2], ':');
        if sync_type || atomic || path_seg || import {
            diag(
                out,
                "D007",
                path,
                t.line,
                format!(
                    "`{}` is a concurrency primitive — threads, channels, locks and \
                     atomics are confined to coordinator/parallel.rs (the conservative \
                     parallel engine, pinned bit-exact against the single-threaded \
                     loop) and util/benchkit.rs; engine code must stay deterministic",
                    t.text
                ),
            );
        }
    }
}

// ---------------------------------------------------------------- D011

/// Files inside the confinement scope that may legitimately construct or
/// hold an `Rng`: the fault-plan generator itself, and the workload
/// generators (arrival-shape entropy is seeded per workload and predates
/// fault injection; it never feeds a recovery decision).
const D011_EXEMPT_FILES: &[&str] =
    &["rust/src/coordinator/faults.rs", "rust/src/coordinator/request.rs"];

fn d011_fault_entropy(path: &str, scan: &Scan, items: &[Item], out: &mut Vec<Diagnostic>) {
    if !path.starts_with("rust/src/coordinator/") || D011_EXEMPT_FILES.contains(&path) {
        return;
    }
    let toks = &scan.tokens;
    let tests = structure::test_line_ranges(items);
    let in_test = |line: u32| tests.iter().any(|&(a, b)| a <= line && line <= b);
    for t in toks.iter() {
        if t.kind == TokKind::Ident && t.text == "Rng" && !in_test(t.line) {
            diag(
                out,
                "D011",
                path,
                t.line,
                "`Rng` on a coordinator path — fault/recovery entropy is confined to \
                 coordinator/faults.rs (seeded FaultPlan streams; request.rs holds the \
                 workload-shape generators); retry and failover decisions must be \
                 deterministic"
                    .to_string(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Diagnostics that would fail `--deny`: suppressed findings are
    /// filtered exactly as the CLI and tier-1 sweep filter them.
    fn lint_at(path: &str, src: &str) -> Vec<Diagnostic> {
        lint_file(path, src).into_iter().filter(|d| !d.allowed).collect()
    }

    /// The full stream, suppressed findings included.
    fn lint_all(path: &str, src: &str) -> Vec<Diagnostic> {
        lint_file(path, src)
    }

    fn rules_of(diags: &[Diagnostic]) -> Vec<(&'static str, u32)> {
        diags.iter().map(|d| (d.rule, d.line)).collect()
    }

    // ---- D001 ---------------------------------------------------------

    const COORD: &str = "rust/src/coordinator/fake.rs";

    #[test]
    fn d001_fires_on_iter_keys_values_drain_retain_and_for_in() {
        let src = "use std::collections::{HashMap, HashSet};\n\
                   struct S { m: HashMap<u32, u32> }\n\
                   fn f(s: &mut S) {\n\
                   let mut h: HashSet<u32> = HashSet::new();\n\
                   for x in &s.m {}\n\
                   let _ = s.m.iter();\n\
                   let _ = s.m.keys();\n\
                   let _ = s.m.values();\n\
                   s.m.retain(|_, _| true);\n\
                   h.drain();\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(
            got,
            vec![
                ("D001", 5),
                ("D001", 6),
                ("D001", 7),
                ("D001", 8),
                ("D001", 9),
                ("D001", 10),
            ]
        );
    }

    #[test]
    fn d001_point_lookups_and_btree_iteration_stay_allowed() {
        let src = "use std::collections::{BTreeMap, HashMap};\n\
                   fn f(m: &mut HashMap<u32, u32>, b: &BTreeMap<u32, u32>) -> Option<u32> {\n\
                   for (k, v) in b.iter() {}\n\
                   m.insert(1, 2);\n\
                   m.remove(&1);\n\
                   m.entry(3).or_default();\n\
                   m.get(&1).copied()\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn d001_ignores_iteration_text_in_strings_and_comments() {
        let src = "fn f(m: &std::collections::HashMap<u32, u32>) {\n\
                   // m.iter() would be nondeterministic\n\
                   /* for x in m {} */\n\
                   let _ = \"m.iter() and m.keys()\";\n\
                   let _ = r#\"for x in m {\"#;\n\
                   let _ = m.get(&1);\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn d001_is_scoped_to_the_deterministic_dirs() {
        let src = "fn f(m: &std::collections::HashMap<u32, u32>) { for x in m {} }\n";
        assert!(!lint_at(COORD, src).is_empty());
        assert!(lint_at("rust/src/cluster/fake.rs", src).iter().any(|d| d.rule == "D001"));
        assert!(lint_at("rust/src/bench/fake.rs", src).iter().any(|d| d.rule == "D001"));
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d001_allow_with_reason_suppresses() {
        let src = "fn f(m: &std::collections::HashMap<u32, u32>) {\n\
                   // pallas-lint: allow(D001, reason = \"order folded through a sort\")\n\
                   let mut v: Vec<u32> = m.keys().copied().collect();\n\
                   v.sort_unstable();\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    // ---- D002 ---------------------------------------------------------

    #[test]
    fn d002_fires_on_method_calls_and_fn_pointers() {
        let src = "fn f(v: &mut Vec<f64>) {\n\
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   v.sort_by(f64::partial_cmp_is_fine_not_this);\n\
                   let _ = f64::partial_cmp;\n\
                   }\n";
        let got = rules_of(&lint_at("rust/src/qnn/fake.rs", src));
        assert_eq!(got, vec![("D002", 2), ("D002", 4)]);
    }

    #[test]
    fn d002_skips_definitions_comments_and_strings() {
        let src = "impl PartialOrd for T {\n\
                   fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n\
                   Some(self.cmp(other))\n\
                   }\n\
                   }\n\
                   // the old partial_cmp().unwrap() scans\n\
                   const S: &str = \"a.partial_cmp(b)\";\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- D003 ---------------------------------------------------------

    #[test]
    fn d003_fires_outside_the_bench_harness() {
        let src = "fn f() {\n\
                   let t = std::time::Instant::now();\n\
                   let s = std::time::SystemTime::now();\n\
                   }\n";
        let got = rules_of(&lint_at("rust/src/coordinator/fake.rs", src));
        assert_eq!(got, vec![("D003", 2), ("D003", 3)]);
        assert!(lint_at("rust/src/util/benchkit.rs", src).is_empty());
        assert!(lint_at("rust/benches/fake.rs", src).is_empty());
    }

    #[test]
    fn d003_ignores_mentions_in_comments_and_strings() {
        let src = "// Instant::now() is banned here\n\
                   const S: &str = \"SystemTime::now\";\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- D004 ---------------------------------------------------------

    #[test]
    fn d004_fires_in_coordinator_non_test_code_only() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   x.unwrap()\n\
                   }\n\
                   fn g(x: Option<u32>) -> u32 {\n\
                   x.expect(\"invariant\")\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn t(x: Option<u32>) -> u32 { x.unwrap() }\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("D004", 2), ("D004", 5)]);
        // outside coordinator/ the rule is silent
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d004_test_fns_and_unwrap_or_variants_are_exempt() {
        let src = "#[test]\n\
                   fn t() { Some(1).unwrap(); }\n\
                   fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n\
                   fn g(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 0) }\n\
                   // x.unwrap() in a comment\n\
                   const S: &str = \".unwrap()\";\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn d004_allow_on_same_or_preceding_line_suppresses() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // pallas-lint: allow(D004, reason = \"checked two lines up\")\n\
                   x.unwrap()\n\
                   }\n\
                   fn g(x: Option<u32>) -> u32 {\n\
                   x.expect(\"y\") // pallas-lint: allow(D004, reason = \"doc'd invariant\")\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    // ---- D005 ---------------------------------------------------------

    #[test]
    fn d005_fires_on_the_known_corruption_shapes_with_exact_lines() {
        let src = "/! The horizontally sharded serving tier\n\
                   fn f() -> u32 { 1 }\n\
                   / [`merge_streams`]: crate::coordinator\n\
                   / FIFO router queue: one front-end\n";
        let got = rules_of(&lint_at("rust/src/qnn/fake.rs", src));
        assert_eq!(got, vec![("D005", 1), ("D005", 3), ("D005", 4)]);
    }

    #[test]
    fn d005_skips_marker_shapes_inside_strings_and_block_comments() {
        let src = "const S: &str = \"\n\
                   / FIFO router queue: one front-end\n\
                   /! not a marker either\n\
                   \";\n\
                   /*\n\
                   / Fleet stepping API\n\
                   */\n\
                   let x = a\n\
                   / f.devices.len() as f64;\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- D006 ---------------------------------------------------------

    #[test]
    fn d006_requires_forbid_on_crate_roots_and_bans_unsafe_tokens() {
        let ok = "#![forbid(unsafe_code)]\npub mod x;\n";
        assert!(lint_at("rust/src/lib.rs", ok).is_empty());
        let missing = "pub mod x;\n";
        let got = rules_of(&lint_at("rust/src/lib.rs", missing));
        assert_eq!(got, vec![("D006", 1)]);
        // non-root files need no attribute, but the token ban is global
        assert!(lint_at("rust/src/qnn/fake.rs", missing).is_empty());
        let tok = "fn f() { unsafe { core::hint::unreachable_unchecked() } }\n";
        assert!(lint_at("rust/src/qnn/fake.rs", tok).iter().any(|d| d.rule == "D006"));
    }

    #[test]
    fn d006_ignores_unsafe_in_comments_and_strings() {
        let src = "#![forbid(unsafe_code)]\n\
                   // NaN-unsafe float compares\n\
                   const S: &str = \"unsafe\";\n";
        assert!(lint_at("rust/src/lib.rs", src).is_empty());
    }

    // ---- D007 ---------------------------------------------------------

    #[test]
    fn d007_fires_on_threads_channels_locks_and_atomics() {
        let src = "use std::sync::{Mutex, Condvar};\n\
                   use std::sync::mpsc;\n\
                   use std::sync::atomic::AtomicUsize;\n\
                   fn f() {\n\
                   let h = std::thread::spawn(|| 1);\n\
                   let l: std::sync::RwLock<u32> = std::sync::RwLock::new(0);\n\
                   let (tx, rx) = mpsc::channel::<u32>();\n\
                   }\n";
        let got = rules_of(&lint_at("rust/src/qnn/fake.rs", src));
        assert_eq!(
            got,
            vec![
                ("D007", 1),
                ("D007", 1),
                ("D007", 2),
                ("D007", 3),
                ("D007", 5),
                ("D007", 6),
                ("D007", 6),
                ("D007", 7),
            ]
        );
    }

    #[test]
    fn d007_is_silent_in_the_reviewed_files() {
        let src = "use std::sync::Mutex;\n\
                   fn f() { let h = std::thread::spawn(|| 1); }\n";
        assert!(lint_at("rust/src/coordinator/parallel.rs", src).is_empty());
        assert!(lint_at("rust/src/util/benchkit.rs", src).is_empty());
        assert!(!lint_at("rust/src/coordinator/shard.rs", src).is_empty());
    }

    #[test]
    fn d007_ignores_bindings_accessors_comments_and_strings() {
        let src = "fn f() -> u32 {\n\
                   let thread = 1;\n\
                   // std::thread::spawn in a comment stays silent\n\
                   let _ = \"Mutex and mpsc::channel\";\n\
                   thread + 1\n\
                   }\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d007_allow_with_reason_suppresses() {
        let src = "// pallas-lint: allow(D007, reason = \"reviewed: measurement-only helper\")\n\
                   use std::sync::Mutex;\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- D008 ---------------------------------------------------------

    #[test]
    fn d008_fires_on_mixed_unit_arithmetic_with_exact_lines() {
        let src = "fn f(lat_us: u64, lat_cycles: u64, e_uj: f64, p_mw: f64) -> u64 {\n\
                   let _ = e_uj + p_mw;\n\
                   lat_us + lat_cycles\n\
                   }\n";
        let got = rules_of(&lint_at("rust/src/qnn/fake.rs", src));
        assert_eq!(got, vec![("D008", 2), ("D008", 3)]);
    }

    #[test]
    fn d008_is_silent_on_strings_comments_and_products() {
        let src = "fn f(base_cycles: u64, k_len: u64, per_cycles: u64) -> u64 {\n\
                   // adding base_us + base_cycles here would mix units\n\
                   let _ = \"a_us + b_cycles\";\n\
                   base_cycles + k_len * per_cycles\n\
                   }\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d008_allow_with_reason_suppresses_but_is_retained() {
        let src = "fn f(a_us: u64, b_ms: u64) -> u64 {\n\
                   // pallas-lint: allow(D008, reason = \"legacy mixed field, tracked\")\n\
                   a_us + b_ms\n\
                   }\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
        let all = lint_all("rust/src/qnn/fake.rs", src);
        assert_eq!(all.len(), 1);
        assert!(all[0].allowed);
        assert_eq!((all[0].rule, all[0].line), ("D008", 3));
    }

    // ---- D009 ---------------------------------------------------------

    #[test]
    fn d009_fires_on_panic_macros_and_indexing_in_coordinator_only() {
        let src = "fn f(xs: &[u64], i: usize) -> u64 {\n\
                   if i >= xs.len() { panic!(\"oob\") }\n\
                   xs[i]\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("D009", 2), ("D009", 3)]);
        // outside the coordinator the panic-surface audit is silent
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d009_ignores_mentions_in_strings_and_comments() {
        let src = "fn f() -> &'static str {\n\
                   // xs[i] and panic!() here are just prose\n\
                   \"xs[i] panic!\"\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn d009_allow_item_covers_the_whole_fn() {
        let src = "// pallas-lint: allow-item(D009, reason = \"ids are dense slab indices\")\n\
                   fn f(xs: &[u64], i: usize, j: usize) -> u64 {\n\
                   let a = xs[i];\n\
                   let b = xs[j];\n\
                   a + b\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
        let all = lint_all(COORD, src);
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|d| d.rule == "D009" && d.allowed));
    }

    #[test]
    fn d009_allow_item_attaches_above_attributes_too() {
        let src = "// pallas-lint: allow-item(D009, reason = \"validated in the ctor\")\n\
                   #[allow(dead_code)]\n\
                   fn f(xs: &[u64], i: usize) -> u64 {\n\
                   xs[i]\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn allow_item_that_attaches_to_nothing_is_a000() {
        let src = "// pallas-lint: allow-item(D009, reason = \"floating\")\n\
                   \n\
                   fn f() -> u32 { 1 }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("A000", 1)]);
    }

    // ---- D010 ---------------------------------------------------------

    #[test]
    fn d010_fires_when_a_rule_has_no_docs_row_and_vice_versa() {
        let mut docs = String::from("# rules\n\n| id | summary |\n| --- | --- |\n");
        for r in RULES {
            if r.id != "D008" {
                docs.push_str(&format!("| `{}` | {} |\n", r.id, r.summary));
            }
        }
        docs.push_str("| `D999` | a ghost rule |\n");
        let got = d010_docs_drift(&docs);
        assert_eq!(got.len(), 2);
        assert!(got[0].message.contains("D008"));
        assert!(got[1].message.contains("D999"));
        assert!(got.iter().all(|d| d.rule == "D010" && d.file == "docs/STATIC_ANALYSIS.md"));
    }

    #[test]
    fn d010_ignores_rule_ids_in_prose_and_later_cells() {
        let mut docs = String::from(
            "D008 in prose is not a row, and `D777` in backticks is not either.\n\n\
             | id | summary |\n| --- | --- |\n\
             | history | D777 was folded into D008 before release |\n",
        );
        for r in RULES {
            docs.push_str(&format!("| `{}` | {} |\n", r.id, r.summary));
        }
        assert!(d010_docs_drift(&docs).is_empty());
    }

    // ---- D011 ---------------------------------------------------------

    #[test]
    fn d011_fires_on_rng_in_coordinator_non_test_code() {
        let src = "use crate::util::rng::Rng;\n\
                   fn retry_with_jitter(rng: &mut Rng) -> f64 {\n\
                   rng.unit_f64() * 100.0\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("D011", 1), ("D011", 2)]);
    }

    #[test]
    fn d011_is_silent_in_exempt_files_tests_and_outside_coordinator() {
        let src = "use crate::util::rng::Rng;\n\
                   fn gen(rng: &mut Rng) -> u64 { rng.next_u64() }\n";
        assert!(lint_at("rust/src/coordinator/faults.rs", src).is_empty());
        assert!(lint_at("rust/src/coordinator/request.rs", src).is_empty());
        assert!(lint_at("rust/src/util/rng.rs", src).is_empty());
        let in_tests = "#[cfg(test)]\n\
                        mod tests {\n\
                        use crate::util::rng::Rng;\n\
                        fn h() { let _ = Rng::new(1); }\n\
                        }\n";
        assert!(lint_at(COORD, in_tests).is_empty());
    }

    #[test]
    fn d011_allow_item_suppresses_with_reason() {
        let src = "// pallas-lint: allow(D011, reason = \"property-test fleet shapes\")\n\
                   use crate::util::rng::Rng;\n\
                   // pallas-lint: allow-item(D011, reason = \"property-test fleet shapes\")\n\
                   fn random_thing(rng: &mut Rng) -> u64 {\n\
                   rng.next_u64()\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
        let all = lint_all(COORD, src);
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|d| d.rule == "D011" && d.allowed));
    }

    // ---- annotations --------------------------------------------------

    #[test]
    fn a000_reasonless_allow_is_a_diagnostic() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   x.unwrap() // pallas-lint: allow(D004)\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("A000", 2), ("D004", 2)]);
    }

    #[test]
    fn a001_stale_allow_is_a_diagnostic() {
        let src = "// pallas-lint: allow(D004, reason = \"nothing here needs it\")\n\
                   fn f() -> u32 { 1 }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("A001", 1)]);
    }

    #[test]
    fn allow_does_not_cross_rules_or_lines() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // pallas-lint: allow(D002, reason = \"wrong rule id\")\n\
                   x.unwrap()\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("A001", 2), ("D004", 3)]);
    }

    #[test]
    fn one_allow_can_cover_several_rules() {
        let src = "fn f(x: Option<u64>, a_us: u64, b_ms: u64) -> u64 {\n\
                   // pallas-lint: allow(D004, D008, reason = \"both checked upstream\")\n\
                   x.unwrap() + (a_us - b_ms)\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
        let all = lint_all(COORD, src);
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|d| d.allowed));
        let rules: Vec<&str> = all.iter().map(|d| d.rule).collect();
        assert_eq!(rules, vec!["D004", "D008"]);
    }

    #[test]
    fn staleness_is_per_rule_id_in_a_multi_id_allow() {
        let src = "fn f(x: Option<u64>) -> u64 {\n\
                   // pallas-lint: allow(D004, D008, reason = \"only D004 fires\")\n\
                   x.unwrap()\n\
                   }\n";
        let got = lint_at(COORD, src);
        assert_eq!(rules_of(&got), vec![("A001", 2)]);
        assert!(got[0].message.contains("allow(D008)"), "{}", got[0].message);
    }

    #[test]
    fn suppressed_diagnostics_are_retained_and_marked() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // pallas-lint: allow(D004, reason = \"checked by caller\")\n\
                   x.unwrap()\n\
                   }\n";
        let all = lint_all(COORD, src);
        assert_eq!(all.len(), 1);
        assert!(all[0].allowed);
        assert!(all[0].to_string().ends_with("(allowed)"));
    }

    #[test]
    fn diagnostics_serialize_to_stable_jsonl() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let all = lint_all(COORD, src);
        assert_eq!(all.len(), 1);
        let line = all[0].to_json();
        assert!(line.starts_with("{\"allowed\":false,\"file\":"), "{line}");
        assert!(line.contains("\"line\":1"), "{line}");
        assert!(line.contains("\"rule\":\"D004\""), "{line}");
        // the message embeds quotes/backticks — the writer must escape
        let parsed = crate::util::json::Json::parse(&line).expect("valid JSON");
        assert_eq!(parsed.get("rule").as_str(), Some("D004"));
        assert_eq!(parsed.get("allowed").as_bool(), Some(false));
    }

    #[test]
    fn every_rule_has_an_explain_text() {
        for r in RULES {
            assert!(!r.explain.trim().is_empty(), "{} lacks an explain", r.id);
            assert!(!r.summary.trim().is_empty(), "{} lacks a summary", r.id);
        }
    }

    #[test]
    fn test_region_tracking_handles_nested_braces() {
        let scan = crate::analysis::scanner::scan(
            "#[cfg(test)]\n\
             mod tests {\n\
             fn a() { if true { let x = Some(1).unwrap(); } }\n\
             }\n\
             fn after(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        let items = crate::analysis::structure::build(&scan);
        let ranges = crate::analysis::structure::test_line_ranges(&items);
        assert_eq!(ranges, vec![(1, 4)]);
    }
}
