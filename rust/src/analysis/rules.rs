//! The `pallas-lint` rule set: determinism & invariant rules D001–D007.
//!
//! Every rule is lexical — it pattern-matches the token stream produced
//! by [`crate::analysis::scanner`] — so rule text inside strings, raw
//! strings, chars, and comments can never fire. Each diagnostic carries
//! a machine-readable rule id and an exact 1-based line, and can be
//! suppressed by an inline annotation **with a mandatory reason** on the
//! same line or the line directly above:
//!
//! ```text
//! // pallas-lint: allow(D004, reason = "documented panic: API contract")
//! ```
//!
//! A reason-less, unknown-rule, or otherwise malformed annotation is
//! itself a diagnostic (A000), and an annotation that suppresses nothing
//! is flagged as stale (A001) — the sweep stays allowlist-exact.
//!
//! See `docs/STATIC_ANALYSIS.md` for the rule catalog and the rationale
//! tying each rule to the repo's bit-exact-replay invariant.

use std::collections::BTreeSet;

use crate::analysis::scanner::{Scan, TokKind, Token};

/// A single lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Machine-readable rule id (`D001`..`D007`, `A000`, `A001`).
    pub rule: &'static str,
    /// Repo-relative path of the offending file.
    pub file: String,
    /// 1-based line of the offending token.
    pub line: u32,
    /// Human explanation.
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Catalog entry for one rule (the `lint --rules` listing and the docs
/// are generated from this table).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Machine-readable id.
    pub id: &'static str,
    /// One-line summary.
    pub summary: &'static str,
    /// Where the rule applies.
    pub scope: &'static str,
}

/// The rule catalog, in id order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "D001",
        summary: "no HashMap/HashSet iteration (iter/keys/values/drain/retain/for-in); \
                  iteration order is nondeterministic and breaks bit-exact replay",
        scope: "rust/src/coordinator, rust/src/cluster, rust/src/bench",
    },
    RuleInfo {
        id: "D002",
        summary: "no partial_cmp calls on floats; f64::total_cmp is the repo rule (NaN-safe, \
                  total order) since PR 5",
        scope: "everywhere",
    },
    RuleInfo {
        id: "D003",
        summary: "no Instant::now/SystemTime::now on simulation paths; wall-clock reads are \
                  confined to the bench harness",
        scope: "everywhere except rust/src/util/benchkit.rs and rust/benches",
    },
    RuleInfo {
        id: "D004",
        summary: "no unwrap()/expect() in coordinator non-test code without a reviewed reason",
        scope: "rust/src/coordinator, outside #[cfg(test)]/#[test] items",
    },
    RuleInfo {
        id: "D005",
        summary: "no corrupted doc-comment markers (`/!`, or a lone `/ ` before prose); \
                  rustdoc drops such lines silently",
        scope: "everywhere (code context only; strings/comments exempt)",
    },
    RuleInfo {
        id: "D006",
        summary: "crate roots carry #![forbid(unsafe_code)] and no unsafe token appears",
        scope: "attribute: rust/src/lib.rs + rust/src/main.rs; token ban: everywhere",
    },
    RuleInfo {
        id: "D007",
        summary: "no concurrency primitives (std::thread, std::sync::mpsc, Mutex/RwLock/\
                  Condvar, atomics) outside the conservative parallel engine; \
                  nondeterministic interleaving must never leak into engine code",
        scope: "everywhere except rust/src/coordinator/parallel.rs and rust/src/util/benchkit.rs",
    },
    RuleInfo {
        id: "A000",
        summary: "malformed pallas-lint annotation (unknown rule, missing or empty reason)",
        scope: "everywhere (engine-generated; not allowable)",
    },
    RuleInfo {
        id: "A001",
        summary: "stale allow annotation: it suppresses no diagnostic",
        scope: "everywhere (engine-generated; not allowable)",
    },
];

/// True for rule ids that may appear in an allow annotation.
pub fn is_known_rule(id: &str) -> bool {
    matches!(id, "D001" | "D002" | "D003" | "D004" | "D005" | "D006" | "D007")
}

/// Lint one file's source text. `path` must be repo-relative with `/`
/// separators — rule scoping matches on it textually.
pub fn lint_file(path: &str, text: &str) -> Vec<Diagnostic> {
    let scan = crate::analysis::scanner::scan(text);
    let mut raw: Vec<Diagnostic> = Vec::new();
    d001_hash_iteration(path, &scan, &mut raw);
    d002_partial_cmp(path, &scan, &mut raw);
    d003_wall_clock(path, &scan, &mut raw);
    d004_unwrap_in_coordinator(path, &scan, &mut raw);
    d005_corrupted_doc_markers(path, text, &scan, &mut raw);
    d006_unsafe(path, &scan, &mut raw);
    d007_concurrency(path, &scan, &mut raw);

    // apply allow annotations: an allow on line L suppresses matching
    // diagnostics on L (trailing comment) and L + 1 (preceding line)
    let mut used = vec![false; scan.allows.len()];
    let mut out: Vec<Diagnostic> = Vec::new();
    for d in raw {
        let mut suppressed = false;
        for (k, a) in scan.allows.iter().enumerate() {
            if a.rule == d.rule && (a.line == d.line || a.line + 1 == d.line) {
                used[k] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            out.push(d);
        }
    }
    for (line, why) in &scan.malformed {
        out.push(Diagnostic {
            rule: "A000",
            file: path.to_string(),
            line: *line,
            message: format!("malformed pallas-lint annotation: {why}"),
        });
    }
    for (k, a) in scan.allows.iter().enumerate() {
        if !used[k] {
            out.push(Diagnostic {
                rule: "A001",
                file: path.to_string(),
                line: a.line,
                message: format!(
                    "stale allow({}) suppresses nothing — remove it (reason was: \"{}\")",
                    a.rule, a.reason
                ),
            });
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

fn diag(out: &mut Vec<Diagnostic>, rule: &'static str, path: &str, line: u32, message: String) {
    out.push(Diagnostic { rule, file: path.to_string(), line, message });
}

fn is_punct(t: &Token, c: char) -> bool {
    t.kind == TokKind::Punct && t.text.len() == 1 && t.text.as_bytes()[0] == c as u8
}

fn is_ident(t: &Token, name: &str) -> bool {
    t.kind == TokKind::Ident && t.text == name
}

// ---------------------------------------------------------------- D001

const D001_DIRS: &[&str] = &["rust/src/coordinator/", "rust/src/cluster/", "rust/src/bench/"];

const D001_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
    "retain_mut",
    "extract_if",
];

/// Names in this file declared (or assigned) with a `HashMap`/`HashSet`
/// type: `name: …HashMap<…>` struct fields and `let` bindings, plus
/// `name = HashMap::new()` assignments. Lexical, per-file — aliases that
/// launder a hash map through another binding are out of scope (see
/// docs/STATIC_ANALYSIS.md, "Known limits").
fn hash_typed_names(toks: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        if !(is_ident(&toks[i], "HashMap") || is_ident(&toks[i], "HashSet")) {
            continue;
        }
        // walk back through type-position tokens to the declaring `:`
        // (or `=` for an inferred binding); give up fast on anything
        // that is not plausibly part of a type
        let mut j = i;
        let mut steps = 0;
        while j > 0 && steps < 32 {
            j -= 1;
            steps += 1;
            let t = &toks[j];
            if is_punct(t, ':') {
                if j > 0 && is_punct(&toks[j - 1], ':') {
                    j -= 1; // `::` path separator — keep walking
                    continue;
                }
                if j > 0 && toks[j - 1].kind == TokKind::Ident {
                    names.insert(toks[j - 1].text.clone());
                }
                break;
            }
            if is_punct(t, '=') {
                let arrow = j + 1 < toks.len() && is_punct(&toks[j + 1], '>');
                if !arrow && j > 0 && toks[j - 1].kind == TokKind::Ident {
                    names.insert(toks[j - 1].text.clone());
                }
                break;
            }
            let type_ish = t.kind == TokKind::Ident
                || t.kind == TokKind::Lifetime
                || is_punct(t, '<')
                || is_punct(t, '>')
                || is_punct(t, ',')
                || is_punct(t, '&')
                || is_punct(t, '(')
                || is_punct(t, ')');
            if !type_ish {
                break;
            }
        }
    }
    names
}

fn d001_hash_iteration(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    if !D001_DIRS.iter().any(|d| path.starts_with(d)) {
        return;
    }
    let toks = &scan.tokens;
    let names = hash_typed_names(toks);
    if names.is_empty() {
        return;
    }
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident || !names.contains(&toks[i].text) {
            continue;
        }
        // `name.iter()` / `self.name.drain(..)` and friends
        if i + 2 < toks.len()
            && is_punct(&toks[i + 1], '.')
            && toks[i + 2].kind == TokKind::Ident
            && D001_ITER_METHODS.contains(&toks[i + 2].text.as_str())
        {
            diag(
                out,
                "D001",
                path,
                toks[i + 2].line,
                format!(
                    "`{}.{}` iterates a hash collection — iteration order is \
                     nondeterministic; use BTreeMap/BTreeSet (or a slab/intrusive \
                     list) when order can reach a report, trace, or event stream",
                    toks[i].text, toks[i + 2].text
                ),
            );
        }
        // `for x in [&mut] [self.]name {`
        if i + 1 < toks.len() && is_punct(&toks[i + 1], '{') {
            let mut j = i;
            while j >= 2 && is_punct(&toks[j - 1], '.') && toks[j - 2].kind == TokKind::Ident {
                j -= 2;
            }
            while j >= 1 && (is_punct(&toks[j - 1], '&') || is_ident(&toks[j - 1], "mut")) {
                j -= 1;
            }
            if j >= 1 && is_ident(&toks[j - 1], "in") {
                diag(
                    out,
                    "D001",
                    path,
                    toks[i].line,
                    format!(
                        "`for … in {}` iterates a hash collection — iteration order \
                         is nondeterministic; use BTreeMap/BTreeSet instead",
                        toks[i].text
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- D002

fn d002_partial_cmp(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        if !is_ident(&toks[i], "partial_cmp") {
            continue;
        }
        let method_call = i >= 1 && is_punct(&toks[i - 1], '.');
        let path_ref = i >= 2 && is_punct(&toks[i - 1], ':') && is_punct(&toks[i - 2], ':');
        if method_call || path_ref {
            diag(
                out,
                "D002",
                path,
                toks[i].line,
                "`partial_cmp` is NaN-unsafe (returns None and panics downstream or \
                 silently mis-sorts); use `f64::total_cmp` — the repo rule since PR 5"
                    .to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------- D003

fn d003_wall_clock(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    if path == "rust/src/util/benchkit.rs" || path.starts_with("rust/benches/") {
        return;
    }
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        let clock = is_ident(&toks[i], "Instant") || is_ident(&toks[i], "SystemTime");
        if clock
            && i + 3 < toks.len()
            && is_punct(&toks[i + 1], ':')
            && is_punct(&toks[i + 2], ':')
            && is_ident(&toks[i + 3], "now")
        {
            diag(
                out,
                "D003",
                path,
                toks[i].line,
                format!(
                    "`{}::now` reads the wall clock — simulated time must come from \
                     the event clock; real-time reads live in util/benchkit.rs and \
                     benches/ (annotate genuine real-path measurements)",
                    toks[i].text
                ),
            );
        }
    }
}

// ---------------------------------------------------------------- D004

/// 1-based inclusive line ranges covered by `#[cfg(test)]` / `#[test]`
/// items (the attribute's item runs to its matching closing brace, or to
/// the terminating semicolon for braceless items).
fn test_line_ranges(toks: &[Token]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        let cfg_test = is_punct(&toks[i], '#')
            && is_punct(&toks[i + 1], '[')
            && i + 6 < toks.len()
            && is_ident(&toks[i + 2], "cfg")
            && is_punct(&toks[i + 3], '(')
            && is_ident(&toks[i + 4], "test")
            && is_punct(&toks[i + 5], ')')
            && is_punct(&toks[i + 6], ']');
        let plain_test = is_punct(&toks[i], '#')
            && is_punct(&toks[i + 1], '[')
            && i + 3 < toks.len()
            && is_ident(&toks[i + 2], "test")
            && is_punct(&toks[i + 3], ']');
        if !cfg_test && !plain_test {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + if cfg_test { 7 } else { 4 };
        // find the item's opening brace (a `;` first means a braceless
        // item — the region ends there)
        let mut open = None;
        while j < toks.len() {
            if is_punct(&toks[j], '{') {
                open = Some(j);
                break;
            }
            if is_punct(&toks[j], ';') {
                break;
            }
            j += 1;
        }
        let Some(open) = open else {
            let end = toks.get(j).map_or(start_line, |t| t.line);
            ranges.push((start_line, end));
            i = j + 1;
            continue;
        };
        let mut depth = 1i32;
        let mut k = open + 1;
        while k < toks.len() && depth > 0 {
            if is_punct(&toks[k], '{') {
                depth += 1;
            } else if is_punct(&toks[k], '}') {
                depth -= 1;
            }
            k += 1;
        }
        let end_line = toks.get(k.saturating_sub(1)).map_or(start_line, |t| t.line);
        ranges.push((start_line, end_line));
        i = k;
    }
    ranges
}

fn d004_unwrap_in_coordinator(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    if !path.starts_with("rust/src/coordinator/") {
        return;
    }
    let toks = &scan.tokens;
    let tests = test_line_ranges(toks);
    let in_test = |line: u32| tests.iter().any(|&(a, b)| a <= line && line <= b);
    for i in 1..toks.len() {
        let name = &toks[i];
        if name.kind != TokKind::Ident || (name.text != "unwrap" && name.text != "expect") {
            continue;
        }
        if !is_punct(&toks[i - 1], '.') || in_test(name.line) {
            continue;
        }
        diag(
            out,
            "D004",
            path,
            name.line,
            format!(
                "`.{}` in coordinator non-test code — return a typed error, or annotate \
                 the documented invariant with an allow(D004) reason",
                name.text
            ),
        );
    }
}

// ---------------------------------------------------------------- D005

/// A line whose first non-whitespace token looks like a doc-comment
/// marker that lost a slash: `/!`, or a lone `/` followed by a space and
/// an uppercase letter, `[`, or a backtick. Legitimate line-wrapped
/// divisions continue with lowercase identifiers, digits or `(`, so they
/// never match.
pub fn is_corrupted_marker(line: &str) -> bool {
    let t = line.trim_start();
    let Some(rest) = t.strip_prefix('/') else {
        return false;
    };
    if rest.starts_with('!') {
        return true;
    }
    match rest.strip_prefix(' ') {
        Some(after) => after.starts_with(|c: char| c.is_ascii_uppercase() || c == '[' || c == '`'),
        None => false,
    }
}

fn d005_corrupted_doc_markers(path: &str, text: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    for (idx, line) in text.lines().enumerate() {
        if scan.line_starts_in_code(idx + 1) && is_corrupted_marker(line) {
            diag(
                out,
                "D005",
                path,
                (idx + 1) as u32,
                format!(
                    "corrupted doc-comment marker (a `/` short of a doc comment — \
                     rustdoc drops the line silently): `{}`",
                    line.trim()
                ),
            );
        }
    }
}

// ---------------------------------------------------------------- D006

const D006_CRATE_ROOTS: &[&str] = &["rust/src/lib.rs", "rust/src/main.rs"];

fn d006_unsafe(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    let toks = &scan.tokens;
    for t in toks {
        if is_ident(t, "unsafe") {
            diag(
                out,
                "D006",
                path,
                t.line,
                "`unsafe` token — the crate forbids unsafe code (#![forbid(unsafe_code)])"
                    .to_string(),
            );
        }
    }
    if !D006_CRATE_ROOTS.contains(&path) {
        return;
    }
    let mut found = false;
    for i in 0..toks.len() {
        if is_punct(&toks[i], '#')
            && i + 7 < toks.len()
            && is_punct(&toks[i + 1], '!')
            && is_punct(&toks[i + 2], '[')
            && is_ident(&toks[i + 3], "forbid")
            && is_punct(&toks[i + 4], '(')
            && is_ident(&toks[i + 5], "unsafe_code")
            && is_punct(&toks[i + 6], ')')
            && is_punct(&toks[i + 7], ']')
        {
            found = true;
            break;
        }
    }
    if !found {
        diag(out, "D006", path, 1, "crate root is missing `#![forbid(unsafe_code)]`".to_string());
    }
}

// ---------------------------------------------------------------- D007

/// Files where concurrency primitives are reviewed and allowed: the
/// conservative parallel engine (whose determinism is pinned byte-exact
/// against the single-threaded loop) and the bench harness (real-time
/// measurement only, never simulation state).
const D007_ALLOWED_FILES: &[&str] =
    &["rust/src/coordinator/parallel.rs", "rust/src/util/benchkit.rs"];

/// Sync-primitive type names banned outside the allowed files.
const D007_TYPES: &[&str] = &["Mutex", "RwLock", "Condvar"];

fn d007_concurrency(path: &str, scan: &Scan, out: &mut Vec<Diagnostic>) {
    if D007_ALLOWED_FILES.contains(&path) {
        return;
    }
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let sync_type = D007_TYPES.contains(&t.text.as_str());
        let atomic = t.text.starts_with("Atomic") && t.text.len() > "Atomic".len();
        // `thread::…` / `mpsc::…` path segments (spawn, scope, channel);
        // a bare `thread` binding or `.thread()` accessor never matches
        let path_seg = (t.text == "thread" || t.text == "mpsc")
            && i + 2 < toks.len()
            && is_punct(&toks[i + 1], ':')
            && is_punct(&toks[i + 2], ':');
        // `use std::sync::mpsc;` and `use std::thread;` imports
        let import = (t.text == "thread" || t.text == "mpsc")
            && i >= 2
            && is_punct(&toks[i - 1], ':')
            && is_punct(&toks[i - 2], ':');
        if sync_type || atomic || path_seg || import {
            diag(
                out,
                "D007",
                path,
                t.line,
                format!(
                    "`{}` is a concurrency primitive — threads, channels, locks and \
                     atomics are confined to coordinator/parallel.rs (the conservative \
                     parallel engine, pinned bit-exact against the single-threaded \
                     loop) and util/benchkit.rs; engine code must stay deterministic",
                    t.text
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_at(path: &str, src: &str) -> Vec<Diagnostic> {
        lint_file(path, src)
    }

    fn rules_of(diags: &[Diagnostic]) -> Vec<(&'static str, u32)> {
        diags.iter().map(|d| (d.rule, d.line)).collect()
    }

    // ---- D001 ---------------------------------------------------------

    const COORD: &str = "rust/src/coordinator/fake.rs";

    #[test]
    fn d001_fires_on_iter_keys_values_drain_retain_and_for_in() {
        let src = "use std::collections::{HashMap, HashSet};\n\
                   struct S { m: HashMap<u32, u32> }\n\
                   fn f(s: &mut S) {\n\
                   let mut h: HashSet<u32> = HashSet::new();\n\
                   for x in &s.m {}\n\
                   let _ = s.m.iter();\n\
                   let _ = s.m.keys();\n\
                   let _ = s.m.values();\n\
                   s.m.retain(|_, _| true);\n\
                   h.drain();\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(
            got,
            vec![
                ("D001", 5),
                ("D001", 6),
                ("D001", 7),
                ("D001", 8),
                ("D001", 9),
                ("D001", 10),
            ]
        );
    }

    #[test]
    fn d001_point_lookups_and_btree_iteration_stay_allowed() {
        let src = "use std::collections::{BTreeMap, HashMap};\n\
                   fn f(m: &mut HashMap<u32, u32>, b: &BTreeMap<u32, u32>) -> Option<u32> {\n\
                   for (k, v) in b.iter() {}\n\
                   m.insert(1, 2);\n\
                   m.remove(&1);\n\
                   m.entry(3).or_default();\n\
                   m.get(&1).copied()\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn d001_ignores_iteration_text_in_strings_and_comments() {
        let src = "fn f(m: &std::collections::HashMap<u32, u32>) {\n\
                   // m.iter() would be nondeterministic\n\
                   /* for x in m {} */\n\
                   let _ = \"m.iter() and m.keys()\";\n\
                   let _ = r#\"for x in m {\"#;\n\
                   let _ = m.get(&1);\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn d001_is_scoped_to_the_deterministic_dirs() {
        let src = "fn f(m: &std::collections::HashMap<u32, u32>) { for x in m {} }\n";
        assert!(!lint_at(COORD, src).is_empty());
        assert!(lint_at("rust/src/cluster/fake.rs", src).iter().any(|d| d.rule == "D001"));
        assert!(lint_at("rust/src/bench/fake.rs", src).iter().any(|d| d.rule == "D001"));
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d001_allow_with_reason_suppresses() {
        let src = "fn f(m: &std::collections::HashMap<u32, u32>) {\n\
                   // pallas-lint: allow(D001, reason = \"order folded through a sort\")\n\
                   let mut v: Vec<u32> = m.keys().copied().collect();\n\
                   v.sort_unstable();\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    // ---- D002 ---------------------------------------------------------

    #[test]
    fn d002_fires_on_method_calls_and_fn_pointers() {
        let src = "fn f(v: &mut Vec<f64>) {\n\
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   v.sort_by(f64::partial_cmp_is_fine_not_this);\n\
                   let _ = f64::partial_cmp;\n\
                   }\n";
        let got = rules_of(&lint_at("rust/src/qnn/fake.rs", src));
        assert_eq!(got, vec![("D002", 2), ("D002", 4)]);
    }

    #[test]
    fn d002_skips_definitions_comments_and_strings() {
        let src = "impl PartialOrd for T {\n\
                   fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n\
                   Some(self.cmp(other))\n\
                   }\n\
                   }\n\
                   // the old partial_cmp().unwrap() scans\n\
                   const S: &str = \"a.partial_cmp(b)\";\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- D003 ---------------------------------------------------------

    #[test]
    fn d003_fires_outside_the_bench_harness() {
        let src = "fn f() {\n\
                   let t = std::time::Instant::now();\n\
                   let s = std::time::SystemTime::now();\n\
                   }\n";
        let got = rules_of(&lint_at("rust/src/coordinator/fake.rs", src));
        assert_eq!(got, vec![("D003", 2), ("D003", 3)]);
        assert!(lint_at("rust/src/util/benchkit.rs", src).is_empty());
        assert!(lint_at("rust/benches/fake.rs", src).is_empty());
    }

    #[test]
    fn d003_ignores_mentions_in_comments_and_strings() {
        let src = "// Instant::now() is banned here\n\
                   const S: &str = \"SystemTime::now\";\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- D004 ---------------------------------------------------------

    #[test]
    fn d004_fires_in_coordinator_non_test_code_only() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   x.unwrap()\n\
                   }\n\
                   fn g(x: Option<u32>) -> u32 {\n\
                   x.expect(\"invariant\")\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn t(x: Option<u32>) -> u32 { x.unwrap() }\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("D004", 2), ("D004", 5)]);
        // outside coordinator/ the rule is silent
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d004_test_fns_and_unwrap_or_variants_are_exempt() {
        let src = "#[test]\n\
                   fn t() { Some(1).unwrap(); }\n\
                   fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n\
                   fn g(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 0) }\n\
                   // x.unwrap() in a comment\n\
                   const S: &str = \".unwrap()\";\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    #[test]
    fn d004_allow_on_same_or_preceding_line_suppresses() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // pallas-lint: allow(D004, reason = \"checked two lines up\")\n\
                   x.unwrap()\n\
                   }\n\
                   fn g(x: Option<u32>) -> u32 {\n\
                   x.expect(\"y\") // pallas-lint: allow(D004, reason = \"doc'd invariant\")\n\
                   }\n";
        assert!(lint_at(COORD, src).is_empty());
    }

    // ---- D005 ---------------------------------------------------------

    #[test]
    fn d005_fires_on_the_known_corruption_shapes_with_exact_lines() {
        let src = "/! The horizontally sharded serving tier\n\
                   fn f() -> u32 { 1 }\n\
                   / [`merge_streams`]: crate::coordinator\n\
                   / FIFO router queue: one front-end\n";
        let got = rules_of(&lint_at("rust/src/qnn/fake.rs", src));
        assert_eq!(got, vec![("D005", 1), ("D005", 3), ("D005", 4)]);
    }

    #[test]
    fn d005_skips_marker_shapes_inside_strings_and_block_comments() {
        let src = "const S: &str = \"\n\
                   / FIFO router queue: one front-end\n\
                   /! not a marker either\n\
                   \";\n\
                   /*\n\
                   / Fleet stepping API\n\
                   */\n\
                   let x = a\n\
                   / f.devices.len() as f64;\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- D006 ---------------------------------------------------------

    #[test]
    fn d006_requires_forbid_on_crate_roots_and_bans_unsafe_tokens() {
        let ok = "#![forbid(unsafe_code)]\npub mod x;\n";
        assert!(lint_at("rust/src/lib.rs", ok).is_empty());
        let missing = "pub mod x;\n";
        let got = rules_of(&lint_at("rust/src/lib.rs", missing));
        assert_eq!(got, vec![("D006", 1)]);
        // non-root files need no attribute, but the token ban is global
        assert!(lint_at("rust/src/qnn/fake.rs", missing).is_empty());
        let tok = "fn f() { unsafe { core::hint::unreachable_unchecked() } }\n";
        assert!(lint_at("rust/src/qnn/fake.rs", tok).iter().any(|d| d.rule == "D006"));
    }

    #[test]
    fn d006_ignores_unsafe_in_comments_and_strings() {
        let src = "#![forbid(unsafe_code)]\n\
                   // NaN-unsafe float compares\n\
                   const S: &str = \"unsafe\";\n";
        assert!(lint_at("rust/src/lib.rs", src).is_empty());
    }

    // ---- D007 ---------------------------------------------------------

    #[test]
    fn d007_fires_on_threads_channels_locks_and_atomics() {
        let src = "use std::sync::{Mutex, Condvar};\n\
                   use std::sync::mpsc;\n\
                   use std::sync::atomic::AtomicUsize;\n\
                   fn f() {\n\
                   let h = std::thread::spawn(|| 1);\n\
                   let l: std::sync::RwLock<u32> = std::sync::RwLock::new(0);\n\
                   let (tx, rx) = mpsc::channel::<u32>();\n\
                   }\n";
        let got = rules_of(&lint_at("rust/src/qnn/fake.rs", src));
        assert_eq!(
            got,
            vec![
                ("D007", 1),
                ("D007", 1),
                ("D007", 2),
                ("D007", 3),
                ("D007", 5),
                ("D007", 6),
                ("D007", 6),
                ("D007", 7),
            ]
        );
    }

    #[test]
    fn d007_is_silent_in_the_reviewed_files() {
        let src = "use std::sync::Mutex;\n\
                   fn f() { let h = std::thread::spawn(|| 1); }\n";
        assert!(lint_at("rust/src/coordinator/parallel.rs", src).is_empty());
        assert!(lint_at("rust/src/util/benchkit.rs", src).is_empty());
        assert!(!lint_at("rust/src/coordinator/shard.rs", src).is_empty());
    }

    #[test]
    fn d007_ignores_bindings_accessors_comments_and_strings() {
        let src = "fn f() -> u32 {\n\
                   let thread = 1;\n\
                   // std::thread::spawn in a comment stays silent\n\
                   let _ = \"Mutex and mpsc::channel\";\n\
                   thread + 1\n\
                   }\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    #[test]
    fn d007_allow_with_reason_suppresses() {
        let src = "// pallas-lint: allow(D007, reason = \"reviewed: measurement-only helper\")\n\
                   use std::sync::Mutex;\n";
        assert!(lint_at("rust/src/qnn/fake.rs", src).is_empty());
    }

    // ---- annotations --------------------------------------------------

    #[test]
    fn a000_reasonless_allow_is_a_diagnostic() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   x.unwrap() // pallas-lint: allow(D004)\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("A000", 2), ("D004", 2)]);
    }

    #[test]
    fn a001_stale_allow_is_a_diagnostic() {
        let src = "// pallas-lint: allow(D004, reason = \"nothing here needs it\")\n\
                   fn f() -> u32 { 1 }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("A001", 1)]);
    }

    #[test]
    fn allow_does_not_cross_rules_or_lines() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // pallas-lint: allow(D002, reason = \"wrong rule id\")\n\
                   x.unwrap()\n\
                   }\n";
        let got = rules_of(&lint_at(COORD, src));
        assert_eq!(got, vec![("A001", 2), ("D004", 3)]);
    }

    #[test]
    fn test_region_tracking_handles_nested_braces() {
        let toks = crate::analysis::scanner::scan(
            "#[cfg(test)]\n\
             mod tests {\n\
             fn a() { if true { let x = Some(1).unwrap(); } }\n\
             }\n\
             fn after(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        let ranges = test_line_ranges(&toks.tokens);
        assert_eq!(ranges, vec![(1, 4)]);
    }
}
