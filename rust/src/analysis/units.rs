//! Units-of-measure checking over the item tree (rules **D008** and
//! **D009**).
//!
//! The codebase carries physical dimensions in identifier suffixes
//! (`_us`, `_cycles`, `_uj`, … — see `docs/STATIC_ANALYSIS.md` for the
//! full table). This pass infers a unit environment per `fn` — parameters
//! by suffix, `let` bindings by suffix or by propagation through simple
//! initializer chains — and flags additive/comparison operators whose two
//! operands carry *different known* units (D008). Multiplicative context
//! is deliberately excluded: `count * cycles` is `cycles`, so an operand
//! adjacent to `*`, `/`, or `%` is never used as evidence.
//!
//! Conversions are recognized by name: a call through `*_to_us` produces
//! `us`, a callee with a unit suffix produces that unit, `len()` produces
//! a count, and a `*_to_<non-unit>` call is trusted as an explicit exit
//! from the unit system.
//!
//! D009 is the panic-surface audit for coordinator non-test paths:
//! panic-family macros and unchecked indexing/slicing must either go away
//! or carry an `allow(D009)` / `allow-item(D009)` annotation stating the
//! invariant that makes them unreachable.

use crate::analysis::scanner::{Scan, TokKind, Token};
use crate::analysis::structure::{walk, Item, ItemKind};
use std::collections::HashMap;

/// Identifier suffix → unit name. `_len`/`_depth` are dimensionless
/// counts. Suffixes are unambiguous; the table is ordered for docs only.
pub const SUFFIX_UNITS: &[(&str, &str)] = &[
    ("_us", "us"),
    ("_ms", "ms"),
    ("_cycles", "cycles"),
    ("_uj", "uj"),
    ("_mw", "mw"),
    ("_rps", "rps"),
    ("_bytes", "bytes"),
    ("_bits", "bits"),
    ("_len", "count"),
    ("_depth", "count"),
];

const KEYWORDS: &[&str] = &[
    "if", "else", "match", "return", "in", "let", "mut", "move", "loop",
    "while", "for", "break", "continue", "as", "ref", "impl", "fn", "pub",
    "use", "where", "dyn", "enum", "struct", "trait", "type", "const",
    "static", "crate", "self", "Self", "super", "mod", "true", "false",
];

fn is_kw(name: &str) -> bool {
    KEYWORDS.contains(&name)
}

fn is_p(t: &Token, c: char) -> bool {
    t.kind == TokKind::Punct && t.text.len() == 1 && t.text.as_bytes()[0] == c as u8
}

/// Unit implied by an identifier's suffix, if any.
pub fn suffix_unit(name: &str) -> Option<&'static str> {
    for (suf, unit) in SUFFIX_UNITS {
        if name.ends_with(suf) && name.len() > suf.len() {
            return Some(unit);
        }
    }
    None
}

/// What a call through `callee` produces:
/// `Some(Some(unit))` — a unit; `Some(None)` — a trusted exit from the
/// unit system (`*_to_<non-unit>`); `None` — opaque, unit unknown.
fn conversion_unit(callee: &str) -> Option<Option<&'static str>> {
    if let Some(pos) = callee.rfind("_to_") {
        let target = &callee[pos + "_to_".len()..];
        for (suf, unit) in SUFFIX_UNITS {
            if target == &suf[1..] {
                return Some(Some(unit));
            }
        }
        return Some(None); // named conversion out of the unit system
    }
    if let Some(u) = suffix_unit(callee) {
        return Some(Some(u));
    }
    if callee == "len" {
        return Some(Some("count"));
    }
    None
}

fn match_close(toks: &[Token], open_idx: usize, hi: usize, open_c: char, close_c: char) -> usize {
    let mut depth = 1i32;
    let mut k = open_idx + 1;
    while k < hi {
        if is_p(&toks[k], open_c) {
            depth += 1;
        } else if is_p(&toks[k], close_c) {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
        k += 1;
    }
    hi.saturating_sub(1)
}

fn match_open(toks: &[Token], close_idx: usize, lo: usize, open_c: char, close_c: char) -> usize {
    let mut depth = 1i32;
    let mut k = close_idx;
    while k > lo {
        k -= 1;
        if is_p(&toks[k], close_c) {
            depth += 1;
        } else if is_p(&toks[k], open_c) {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
    }
    lo
}

/// Unit of the expression `[lo, hi)` if it is a simple chain:
/// `[& mut *]* ident (.field | ::seg | [..] | (..) | ?)* [as ty]`.
/// Returns `(unit, display_name)` — unit `None` when unknown.
fn eval_chain(
    toks: &[Token],
    mut lo: usize,
    mut hi: usize,
    env: &HashMap<&str, &'static str>,
) -> (Option<&'static str>, String) {
    // strip a trailing top-level `as <ty>` cast
    let mut depth = 0i32;
    let mut k = lo;
    while k < hi {
        let t = &toks[k];
        if t.kind == TokKind::Punct && matches!(t.text.as_str(), "(" | "[" | "{") {
            depth += 1;
        } else if t.kind == TokKind::Punct && matches!(t.text.as_str(), ")" | "]" | "}") {
            depth -= 1;
        } else if depth == 0 && t.kind == TokKind::Ident && t.text == "as" {
            hi = k;
            break;
        }
        k += 1;
    }
    // leading borrows / derefs
    while lo < hi
        && (is_p(&toks[lo], '&')
            || is_p(&toks[lo], '*')
            || (toks[lo].kind == TokKind::Ident && toks[lo].text == "mut"))
    {
        lo += 1;
    }
    // fully parenthesized: recurse
    if lo < hi && is_p(&toks[lo], '(') && match_close(toks, lo, hi, '(', ')') == hi - 1 {
        return eval_chain(toks, lo + 1, hi - 1, env);
    }
    if lo >= hi || toks[lo].kind != TokKind::Ident || is_kw(&toks[lo].text) {
        return (None, String::new());
    }
    let mut cur: &str = &toks[lo].text;
    let mut unit: Option<&'static str> = env.get(cur).copied().or_else(|| suffix_unit(cur));
    let mut k = lo + 1;
    while k < hi {
        let t = &toks[k];
        if is_p(t, '.') && k + 1 < hi && toks[k + 1].kind == TokKind::Ident {
            cur = &toks[k + 1].text;
            unit = suffix_unit(cur);
            k += 2;
        } else if is_p(t, ':')
            && k + 1 < hi
            && is_p(&toks[k + 1], ':')
            && k + 2 < hi
            && toks[k + 2].kind == TokKind::Ident
        {
            cur = &toks[k + 2].text;
            unit = suffix_unit(cur);
            k += 3;
        } else if is_p(t, '[') {
            k = match_close(toks, k, hi, '[', ']') + 1; // indexing keeps the unit
        } else if is_p(t, '(') {
            match conversion_unit(cur) {
                Some(Some(u)) => unit = Some(u),
                Some(None) => return (None, cur.to_string()), // trusted exit
                None => return (None, String::new()),         // opaque call
            }
            k = match_close(toks, k, hi, '(', ')') + 1;
        } else if is_p(t, '?') {
            k += 1;
        } else {
            return (None, String::new()); // not a simple chain
        }
    }
    (unit, cur.to_string())
}

/// `name → unit` environment for one fn: params by suffix, then lets in
/// initializer source order (suffix first, else propagation through a
/// simple RHS chain).
fn fn_env<'a>(scan: &'a Scan, fn_item: &'a Item) -> HashMap<&'a str, &'static str> {
    let mut env: HashMap<&str, &'static str> = HashMap::new();
    for p in &fn_item.params {
        if let Some(u) = suffix_unit(&p.name) {
            env.insert(p.name.as_str(), u);
        }
    }
    let mut lets: Vec<&Item> = Vec::new();
    walk(&fn_item.children, &mut |it| {
        if it.kind == ItemKind::Let {
            lets.push(it);
        }
    });
    lets.sort_by_key(|it| it.rhs.map(|(lo, _)| lo).unwrap_or(usize::MAX));
    for it in lets {
        let mut u = suffix_unit(&it.name);
        if u.is_none() {
            if let Some((lo, hi)) = it.rhs {
                u = eval_chain(&scan.tokens, lo, hi, &env).0;
            }
        }
        if let Some(u) = u {
            env.insert(it.name.as_str(), u);
        }
    }
    env
}

/// Token range `[a, end_idx + 1)` of the postfix chain ending at
/// `end_idx`, or `None` when the left operand is not a simple chain.
fn left_operand(toks: &[Token], end_idx: usize, lo: usize) -> Option<(usize, usize)> {
    let mut k = end_idx;
    if k < lo {
        return None;
    }
    loop {
        let t = &toks[k];
        if is_p(t, ')') {
            let open = match_open(toks, k, lo, '(', ')');
            if open == lo && !is_p(&toks[lo], '(') {
                return None;
            }
            if open == 0 {
                return None;
            }
            k = open - 1;
        } else if is_p(t, ']') {
            let open = match_open(toks, k, lo, '[', ']');
            if open == lo && !is_p(&toks[lo], '[') {
                return None;
            }
            if open == 0 {
                return None;
            }
            k = open - 1;
        } else if t.kind == TokKind::Ident && !is_kw(&t.text) {
            if k >= lo + 1 && is_p(&toks[k - 1], '.') {
                if k < 2 {
                    return None;
                }
                k -= 2;
            } else if k >= lo + 2 && is_p(&toks[k - 1], ':') && is_p(&toks[k - 2], ':') {
                if k < 3 {
                    return None;
                }
                k -= 3;
            } else {
                return Some((k, end_idx + 1));
            }
        } else {
            return None;
        }
        if k < lo {
            return None;
        }
    }
}

/// Token range `[start, k)` of the chain beginning at `start_idx`, or
/// `None` when the right operand is not a simple chain.
fn right_operand(toks: &[Token], start_idx: usize, hi: usize) -> Option<(usize, usize)> {
    let mut k = start_idx;
    while k < hi
        && (is_p(&toks[k], '&')
            || is_p(&toks[k], '*')
            || (toks[k].kind == TokKind::Ident && toks[k].text == "mut"))
    {
        k += 1;
    }
    if k >= hi || toks[k].kind != TokKind::Ident || is_kw(&toks[k].text) {
        return None;
    }
    let start = k;
    k += 1;
    while k < hi {
        let t = &toks[k];
        if is_p(t, '.') && k + 1 < hi && toks[k + 1].kind == TokKind::Ident {
            k += 2;
        } else if is_p(t, ':')
            && k + 1 < hi
            && is_p(&toks[k + 1], ':')
            && k + 2 < hi
            && toks[k + 2].kind == TokKind::Ident
        {
            k += 3;
        } else if is_p(t, '[') {
            k = match_close(toks, k, hi, '[', ']') + 1;
        } else if is_p(t, '(') {
            k = match_close(toks, k, hi, '(', ')') + 1;
        } else if is_p(t, '?') {
            k += 1;
        } else {
            break;
        }
    }
    Some((start, k))
}

const TWOCHAR_FIRSTS: &str = "=!<>+-*/%&|^";

/// Every additive / comparison operator site in `[lo, hi)`:
/// `(op, left_end_idx, right_start_idx, line)`.
fn op_sites(toks: &[Token], lo: usize, hi: usize) -> Vec<(&'static str, usize, usize, u32)> {
    let mut out = Vec::new();
    let mut k = lo;
    while k < hi {
        let t = &toks[k];
        if t.kind != TokKind::Punct {
            k += 1;
            continue;
        }
        let c = t.text.as_str();
        let nxt = if k + 1 < hi && toks[k + 1].kind == TokKind::Punct {
            toks[k + 1].text.as_str()
        } else {
            ""
        };
        let prv = if k >= 1 && k - 1 >= lo && toks[k - 1].kind == TokKind::Punct {
            toks[k - 1].text.as_str()
        } else {
            ""
        };
        match c {
            "+" => {
                if nxt == "=" {
                    out.push(("+=", k.wrapping_sub(1), k + 2, t.line));
                    k += 2;
                    continue;
                }
                out.push(("+", k.wrapping_sub(1), k + 1, t.line));
            }
            "-" => {
                if nxt == ">" {
                    k += 2;
                    continue;
                }
                if nxt == "=" {
                    out.push(("-=", k.wrapping_sub(1), k + 2, t.line));
                    k += 2;
                    continue;
                }
                out.push(("-", k.wrapping_sub(1), k + 1, t.line));
            }
            "<" => {
                if prv == "<" || prv == ":" || nxt == "<" {
                    k += 1;
                    continue;
                }
                if nxt == "=" {
                    out.push(("<=", k.wrapping_sub(1), k + 2, t.line));
                    k += 2;
                    continue;
                }
                out.push(("<", k.wrapping_sub(1), k + 1, t.line));
            }
            ">" => {
                if prv == ">" || prv == "-" || prv == "=" || nxt == ">" {
                    k += 1;
                    continue;
                }
                if nxt == "=" {
                    out.push((">=", k.wrapping_sub(1), k + 2, t.line));
                    k += 2;
                    continue;
                }
                out.push((">", k.wrapping_sub(1), k + 1, t.line));
            }
            "=" if nxt == "=" && (prv.is_empty() || !TWOCHAR_FIRSTS.contains(prv)) => {
                out.push(("==", k.wrapping_sub(1), k + 2, t.line));
                k += 2;
                continue;
            }
            "=" if prv == "!" => {
                out.push(("!=", k.wrapping_sub(2), k + 1, t.line));
            }
            _ => {}
        }
        k += 1;
    }
    out
}

/// A raw finding before allow application: `(rule, line, message)`.
pub type UnitsFinding = (&'static str, u32, String);

fn d008_fn(scan: &Scan, fn_item: &Item, child_fn_spans: &[(usize, usize)], out: &mut Vec<UnitsFinding>) {
    let env = fn_env(scan, fn_item);
    let toks = &scan.tokens;
    let (lo, hi) = match fn_item.body {
        Some(b) => b,
        None => return,
    };
    for (op, le, rs, line) in op_sites(toks, lo, hi) {
        if le == usize::MAX || le < lo {
            continue;
        }
        if child_fn_spans.iter().any(|&(a, b)| a <= le && le < b) {
            continue;
        }
        let (l_lo, l_hi) = match left_operand(toks, le, lo) {
            Some(r) => r,
            None => continue,
        };
        // an operand adjacent to * / % is part of a product — its unit is
        // not the identifier's unit (count * cycles is cycles), so skip
        if l_lo > lo
            && toks[l_lo - 1].kind == TokKind::Punct
            && matches!(toks[l_lo - 1].text.as_str(), "*" | "/" | "%")
        {
            continue;
        }
        let (lu, lname) = eval_chain(toks, l_lo, l_hi, &env);
        let lu = match lu {
            Some(u) => u,
            None => continue,
        };
        let (r_lo, r_hi) = match right_operand(toks, rs, hi) {
            Some(r) => r,
            None => continue,
        };
        if r_hi < hi
            && toks[r_hi].kind == TokKind::Punct
            && matches!(toks[r_hi].text.as_str(), "*" | "/" | "%")
        {
            continue;
        }
        let (ru, rname) = eval_chain(toks, r_lo, r_hi, &env);
        let ru = match ru {
            Some(u) => u,
            None => continue,
        };
        if lu != ru {
            out.push((
                "D008",
                line,
                format!(
                    "`{lname}` ({lu}) {op} `{rname}` ({ru}) mixes units — \
                     convert through a named `*_to_*` fn or fix the operand"
                ),
            ));
        }
    }
}

const PANIC_MACROS: &[&str] = &[
    "panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne",
];

fn d009_fn(scan: &Scan, fn_item: &Item, child_fn_spans: &[(usize, usize)], out: &mut Vec<UnitsFinding>) {
    let toks = &scan.tokens;
    let (lo, hi) = match fn_item.body {
        Some(b) => b,
        None => return,
    };
    let mut k = lo;
    while k < hi {
        if child_fn_spans.iter().any(|&(a, b)| a <= k && k < b) {
            k += 1;
            continue;
        }
        let t = &toks[k];
        if t.kind == TokKind::Ident
            && PANIC_MACROS.contains(&t.text.as_str())
            && k + 1 < hi
            && is_p(&toks[k + 1], '!')
        {
            out.push((
                "D009",
                t.line,
                format!(
                    "`{}!` on a coordinator non-test path — return a typed \
                     error or annotate the invariant with allow(D009)",
                    t.text
                ),
            ));
            k += 2;
            continue;
        }
        if is_p(t, '[') && k > lo {
            let prev = &toks[k - 1];
            let indexable = (prev.kind == TokKind::Ident
                && !is_kw(&prev.text)
                && prev.text != "self")
                || is_p(prev, ')')
                || is_p(prev, ']');
            if indexable {
                let close = match_close(toks, k, hi, '[', ']');
                let inner = &toks[k + 1..close.max(k + 1)];
                let literal = inner.len() == 1 && inner[0].kind == TokKind::Num;
                let full_range =
                    inner.len() == 2 && is_p(&inner[0], '.') && is_p(&inner[1], '.');
                if !literal && !full_range {
                    out.push((
                        "D009",
                        t.line,
                        "indexing/slicing can panic on a coordinator non-test \
                         path — use get()/checked access or annotate the \
                         bounds invariant with allow(D009)"
                            .to_string(),
                    ));
                }
                k = close + 1;
                continue;
            }
        }
        k += 1;
    }
}

/// Which of the units-layer rules to run.
#[derive(Clone, Copy)]
pub struct UnitsRules {
    /// Run the mixed-unit arithmetic check (all non-test fns, tree-wide).
    pub d008: bool,
    /// Run the panic-surface audit (coordinator non-test fns only).
    pub d009: bool,
}

/// Run the enabled units-layer rules over every non-test `fn` in the
/// tree. Nested fns are excluded from their parent's scan (each gets its
/// own visit).
pub fn fn_units_pass(scan: &Scan, items: &[Item], rules: UnitsRules) -> Vec<UnitsFinding> {
    let mut out = Vec::new();
    let mut fns: Vec<&Item> = Vec::new();
    walk(items, &mut |it| {
        if it.kind == ItemKind::Fn && it.body.is_some() {
            fns.push(it);
        }
    });
    for f in fns {
        if f.is_test {
            continue;
        }
        let mut spans: Vec<(usize, usize)> = Vec::new();
        walk(&f.children, &mut |c| {
            if c.kind == ItemKind::Fn {
                if let Some(b) = c.body {
                    spans.push(b);
                }
            }
        });
        if rules.d008 {
            d008_fn(scan, f, &spans, &mut out);
        }
        if rules.d009 {
            d009_fn(scan, f, &spans, &mut out);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::scan;
    use crate::analysis::structure::build;

    fn run(src: &str, rules: UnitsRules) -> Vec<(u32, String)> {
        let s = scan(src);
        let items = build(&s);
        fn_units_pass(&s, &items, rules)
            .into_iter()
            .map(|(_, line, msg)| (line, msg))
            .collect()
    }

    const D008_ONLY: UnitsRules = UnitsRules { d008: true, d009: false };
    const D009_ONLY: UnitsRules = UnitsRules { d008: false, d009: true };

    #[test]
    fn mixed_unit_addition_fires() {
        let src = "fn f(lat_us: u64, lat_cycles: u64) -> u64 {\n\
                   lat_us + lat_cycles\n\
                   }\n";
        let got = run(src, D008_ONLY);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 2);
        assert!(got[0].1.contains("(us)"), "{}", got[0].1);
        assert!(got[0].1.contains("(cycles)"), "{}", got[0].1);
    }

    #[test]
    fn same_unit_and_unknown_operands_stay_silent() {
        let src = "fn f(a_us: u64, b_us: u64, n: u64) -> u64 {\n\
                   let c_us = a_us + b_us;\n\
                   c_us + n\n\
                   }\n";
        assert!(run(src, D008_ONLY).is_empty());
    }

    #[test]
    fn unit_propagates_through_simple_lets() {
        let src = "fn f(start_us: u64, budget_ms: u64) {\n\
                   let deadline = start_us;\n\
                   if deadline > budget_ms {}\n\
                   }\n";
        let got = run(src, D008_ONLY);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 3);
    }

    #[test]
    fn named_conversions_are_trusted() {
        let src = "fn f(t_us: u64, b_ms: u64) -> bool {\n\
                   us_to_ms(t_us) > b_ms\n\
                   }\n";
        assert!(run(src, D008_ONLY).is_empty());
    }

    #[test]
    fn products_are_excluded_from_unit_evidence() {
        let src = "fn f(base_cycles: u64, k_len: u64, per_cycles: u64) -> u64 {\n\
                   base_cycles + k_len * per_cycles\n\
                   }\n";
        assert!(run(src, D008_ONLY).is_empty());
    }

    #[test]
    fn comparison_between_units_fires() {
        let src = "fn f(t_us: u64, e_uj: u64) -> bool {\n\
                   t_us >= e_uj\n\
                   }\n";
        let got = run(src, D008_ONLY);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 2);
    }

    #[test]
    fn mention_in_string_or_comment_does_not_fire() {
        let src = "fn f() -> &'static str {\n\
                   // a_us + b_cycles would mix units\n\
                   \"a_us + b_cycles\"\n\
                   }\n";
        assert!(run(src, D008_ONLY).is_empty());
    }

    #[test]
    fn test_fns_are_exempt_from_both_rules() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   #[test]\n\
                   fn t(xs: Vec<u64>, a_us: u64, b_ms: u64) {\n\
                   let _ = xs[3] + a_us - b_ms;\n\
                   panic!(\"boom\");\n\
                   }\n\
                   }\n";
        assert!(run(src, UnitsRules { d008: true, d009: true }).is_empty());
    }

    #[test]
    fn panic_macros_and_indexing_fire_d009() {
        let src = "fn f(xs: &[u64], i: usize) -> u64 {\n\
                   if i > xs.len() { panic!(\"oob\") }\n\
                   xs[i]\n\
                   }\n";
        let got = run(src, D009_ONLY);
        let lines: Vec<u32> = got.iter().map(|g| g.0).collect();
        assert_eq!(lines, vec![2, 3]);
        assert!(got[0].1.contains("`panic!`"));
        assert!(got[1].1.contains("indexing/slicing"));
    }

    #[test]
    fn literal_index_full_range_and_debug_assert_are_exempt() {
        let src = "fn f(xs: &[u64; 4]) -> u64 {\n\
                   debug_assert!(xs.len() == 4);\n\
                   let all = &xs[..];\n\
                   let _ = all;\n\
                   xs[0]\n\
                   }\n";
        assert!(run(src, D009_ONLY).is_empty());
    }

    #[test]
    fn nested_fns_are_scanned_independently_not_doubly() {
        let src = "fn outer(a_us: u64) -> u64 {\n\
                   fn inner(b_ms: u64, c_us: u64) -> u64 { b_ms + c_us }\n\
                   inner(a_us, a_us)\n\
                   }\n";
        let got = run(src, D008_ONLY);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 2);
    }
}
