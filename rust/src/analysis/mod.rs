//! `pallas-lint` — a std-only static-analysis engine enforcing the
//! repo's determinism & invariant rules.
//!
//! Every engine change in PRs 1–5 was proven bit-exact against a
//! retained oracle (event-vs-sync, unified-vs-two-phase,
//! indexed-vs-naive), and the paper's 27-kernel cycle models stay
//! trustworthy only because replays reproduce to the bit. That
//! discipline used to be defended by convention alone: one iterated
//! `HashMap`, one `partial_cmp` on an `f64`, or one wall-clock read on
//! a simulation path silently breaks the oracle properties. This module
//! turns the convention into tooling:
//!
//! * [`scanner`] — a real Rust token scanner (line/block/doc comments,
//!   string/raw-string/char/byte literals, nesting) so rules never fire
//!   on prose;
//! * [`structure`] — a brace-matched item tree (modules, fns with param
//!   lists, impls, struct/enum fields, let bindings, exact line spans)
//!   built over the token stream; the structural base for D004's test
//!   exemption and the units layer;
//! * [`units`] — units-of-measure inference from identifier suffixes
//!   (`_us`, `_cycles`, `_uj`, …) powering D008 (mixed-unit arithmetic)
//!   and D009 (coordinator panic-surface audit);
//! * [`rules`] — the rule set D001–D010 with machine-readable ids,
//!   `file:line` diagnostics, JSONL serialization, and a
//!   reason-carrying `// pallas-lint: allow(<rules>, reason = "...")` /
//!   `allow-item(…)` escape hatch (multi-id, per-id staleness);
//! * [`lint_root`] — the repo sweep over `rust/` + `examples/` plus the
//!   sweep-level docs-drift check (D010), exposed as the `pulpnn lint`
//!   CLI subcommand and enforced in tier-1 by
//!   `rust/tests/static_analysis.rs`.
//!
//! The rule catalog, the unit-suffix table, and the rationale tying each
//! rule to the bit-exact-replay invariant live in
//! `docs/STATIC_ANALYSIS.md`.

pub mod rules;
pub mod scanner;
pub mod structure;
pub mod units;

use std::path::{Path, PathBuf};

pub use rules::{lint_file, Diagnostic, RuleInfo, RULES};

/// Result of a full-tree sweep.
#[derive(Debug)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All surviving diagnostics, ordered by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
}

/// The directories a sweep covers, relative to the lint root.
pub const SWEEP_DIRS: &[&str] = &["rust", "examples"];

/// Collect every `.rs` file under the sweep directories of `root`, as
/// repo-relative `/`-separated paths in sorted (deterministic) order.
pub fn sweep_paths(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut files = Vec::new();
    let mut any = false;
    for dir in SWEEP_DIRS {
        let d = root.join(dir);
        if d.is_dir() {
            any = true;
            walk(&d, &mut files)?;
        }
    }
    if !any {
        return Err(format!(
            "lint root `{}` has none of the sweep directories {:?}",
            root.display(),
            SWEEP_DIRS
        ));
    }
    files.sort();
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let path = entry.map_err(|e| format!("reading {}: {e}", dir.display()))?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The docs file whose rule table D010 diffs against the catalog.
pub const DOCS_CATALOG: &str = "docs/STATIC_ANALYSIS.md";

/// Sweep `rust/` + `examples/` under `root` and lint every file, then
/// run the sweep-level docs-drift check (D010) against
/// `docs/STATIC_ANALYSIS.md`. A missing docs file is itself drift —
/// every registered rule reports its row as absent.
pub fn lint_root(root: &Path) -> Result<LintReport, String> {
    let files = sweep_paths(root)?;
    let mut diagnostics = Vec::new();
    let files_scanned = files.len();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let rel = relative_key(root, path);
        diagnostics.extend(rules::lint_file(&rel, &text));
    }
    let docs_text = std::fs::read_to_string(root.join(DOCS_CATALOG)).unwrap_or_default();
    diagnostics.extend(rules::d010_docs_drift(&docs_text));
    Ok(LintReport { files_scanned, diagnostics })
}

/// Repo-relative `/`-separated path used for rule scoping and display.
fn relative_key(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_catalog_ids_are_unique_and_sorted() {
        let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(ids, sorted, "rule catalog must stay unique and id-ordered");
    }

    #[test]
    fn allowable_rules_are_exactly_the_d_rules() {
        for r in RULES {
            let is_d = r.id.starts_with('D');
            assert_eq!(
                rules::is_known_rule(r.id),
                is_d,
                "allow annotations accept exactly the D-rules, got {}",
                r.id
            );
        }
    }

    #[test]
    fn relative_keys_use_forward_slashes() {
        let root = Path::new("/repo");
        let p = Path::new("/repo/rust/src/lib.rs");
        assert_eq!(relative_key(root, p), "rust/src/lib.rs");
    }
}
