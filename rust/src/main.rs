//! `pulpnn` — the CLI for the mixed-precision QNN reproduction.
//!
//! Evaluation commands regenerate every table/figure of the paper
//! (DESIGN.md §5); runtime commands load the AOT'd JAX/Pallas artifacts
//! into the artifact runtime and run/serve/verify them against the golden chain.

#![forbid(unsafe_code)]

use pulpnn_mp::bench::{ablate, figures};
use pulpnn_mp::coordinator::{
    gap8_mixed_devices, merge_streams, ClosedLoopSource, DegradePolicy, Device, ExecMode,
    FaultParams, FaultPlan, Fleet, FleetConfig, Policy, QueueDiscipline, Request, RetryPolicy,
    ShardConfig, ShardedFleet, TraceSource, VariantTable, Workload, DEFAULT_WAKEUP_CYCLES,
};
use pulpnn_mp::energy::{DeviceClass, GAP8_HP, GAP8_LP};
use pulpnn_mp::util::stats::percentile;
use pulpnn_mp::kernels::netrun::GapBackend;
use pulpnn_mp::qnn::network::demo_cnn;
use pulpnn_mp::qnn::tensor::QTensor;
use pulpnn_mp::runtime::{verify_artifact, Manifest, Runtime};
use pulpnn_mp::util::cli::Args;
use pulpnn_mp::util::rng::Rng;
use pulpnn_mp::util::table::{f, Table};

const USAGE: &str = "\
pulpnn — mixed-precision QNN kernels for extreme-edge devices (CF'20 reproduction)

USAGE: pulpnn <command> [options]

evaluation (regenerates the paper's results):
  fig4        single-core linear MACs/cycle by weight precision
  table1      QntPack overhead (cycles/output pixel) by ofmap precision
  fig5        8-core GAP-8 speed-up over STM32H7/STM32L4 (27 kernels)
  fig6        energy per layer: GAP-8 LP/HP vs STM32H7 vs STM32L4
  peak        the 16 MACs/cycle octa-core claim
  speedup     parallel scaling 1->8 cores (~7.5x claim)
  innerloop   14/72/140 cycles/iteration claim + ISA-simulator cross-check
  ablate      design ablations (bext, hwloops, TCDM banks, thresholds)
  sweep       all 27 kernels: single-core and 8-core MACs/cycle
  all         fig4 + table1 + fig5 + fig6 + peak + speedup + innerloop

networks & runtime:
  run         run the demo CNN (or --spec file.json) on the simulated cluster
  footprint   MobileNetV1 mixed-precision memory-footprint analysis
  infer       execute an AOT artifact on the artifact runtime (--name, --artifacts DIR)
  verify      verify all artifacts: runtime == python golden == rust golden == kernels
  serve       edge-fleet serving simulation (--devices N --rate RPS
              --queue-bound N --batch K --wakeup-cycles C ...); scale it
              out with --shards K --tenants T --repeat-ratio F --cache
              --cache-capacity N --cache-quota N --router-us US
              --switch-cycles C --policy tenancy; run the K shard
              engines on real OS threads with --threads T (conservative
              parallel DES, bit-identical output); schedule it with
              --discipline fifo|edf --steal; drive it closed-loop with
              --closed-loop CLIENTS --think-us US (composes with the
              sharded tier: --closed-loop N --shards K feeds completions
              back across routers, fleets and the cache), or
              record/replay arrival traces with --trace-out/--trace-in;
              brownout mode: --brownout WATERMARK serves a cheaper
              precision variant instead of shedding once a queue passes
              the watermark (--floors NET:MINQ,.. pins per-tenant
              accuracy floors), and --device-classes lp,hp,m7,l4 builds
              a heterogeneous fleet from the paper's measured classes;
              fault injection: --mtbf-us US generates seeded per-device
              crash/recover cycles (--mttr-us US mean repair,
              --straggler F stretches a recovering device's service by F)
              recovered by bounded retries (--retry-budget N, 0 = fail on
              first crash), and --fault-trace-in/--fault-trace-out FILE
              replay/record the fault schedule as JSONL
  emit-spec   print the demo network spec JSON (shared rust/python format)

maintenance:
  lint        run the pallas-lint determinism/invariant rules over the
              repo sources (--root DIR, default `.`; --deny exits
              non-zero on any active diagnostic — the CI mode; --rules
              prints the rule catalog; --explain RULE prints one rule's
              rationale; --format text|json — json emits one JSON object
              per diagnostic, allowed ones included, keys
              allowed/file/line/message/rule)

common options:
  --seed N           workload seed (default 2020)
  --artifacts DIR    artifact directory (default: artifacts)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let mut args = Args::parse(argv[1..].to_vec());
    let seed = args.opt_u64("seed", 2020);
    let code = match cmd.as_str() {
        "fig4" => {
            print!("{}", figures::fig4(seed).1);
            0
        }
        "table1" => {
            print!("{}", figures::table1(seed).1);
            0
        }
        "fig5" => {
            print!("{}", figures::fig5(seed).1);
            0
        }
        "fig6" => {
            print!("{}", figures::fig6(seed).1);
            0
        }
        "peak" => {
            print!("{}", figures::peak(seed).1);
            0
        }
        "speedup" => {
            print!("{}", figures::speedup(seed).1);
            0
        }
        "innerloop" => {
            print!("{}", figures::innerloop());
            0
        }
        "ablate" => {
            print!("{}", ablate::all(seed));
            0
        }
        "all" => {
            for part in [
                figures::fig4(seed).1,
                figures::table1(seed).1,
                figures::fig5(seed).1,
                figures::fig6(seed).1,
                figures::peak(seed).1,
                figures::speedup(seed).1,
                figures::innerloop(),
            ] {
                println!("{part}");
            }
            0
        }
        "sweep" => cmd_sweep(seed),
        "run" => cmd_run(&mut args, seed),
        "footprint" => cmd_footprint(),
        "infer" => cmd_infer(&mut args),
        "verify" => cmd_verify(&mut args),
        "serve" => cmd_serve(&mut args, seed),
        "lint" => cmd_lint(&mut args),
        "emit-spec" => {
            println!("{}", demo_cnn().to_json());
            0
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown command `{other}`\n\n{USAGE}");
            2
        }
    };
    if let Err(e) = args.finish() {
        eprintln!("warning: {e}");
    }
    std::process::exit(code);
}

fn cmd_sweep(seed: u64) -> i32 {
    use pulpnn_mp::kernels::{conv_parallel, Engine, GAP8_TCDM_BANKS};
    use pulpnn_mp::qnn::types::Precision;
    let mut t = Table::new(vec![
        "kernel", "1-core MACs/cyc", "8-core MACs/cyc", "8-core cycles", "speed-up",
    ]);
    for prec in Precision::all() {
        let (kernel, x) = figures::reference_case(prec, seed);
        let mut e = Engine::single_core();
        let (_, s1) = kernel.run(&mut e, &x);
        let run8 = conv_parallel(&kernel, &x, 8, GAP8_TCDM_BANKS);
        t.row(vec![
            prec.kernel_name(),
            f(s1.macs_per_cycle(), 3),
            f(run8.macs_per_cycle(), 3),
            run8.cycles.to_string(),
            format!("{}x", f(s1.cycles as f64 / run8.cycles as f64, 2)),
        ]);
    }
    println!("All 27 mixed-precision kernels on the Reference Layer:\n");
    print!("{}", t.render());
    0
}

fn cmd_lint(args: &mut Args) -> i32 {
    let root = args.opt("root", ".");
    let deny = args.flag("deny");
    let format = args.opt("format", "text");
    if let Some(id) = args.opt_maybe("explain") {
        let Some(r) = pulpnn_mp::analysis::RULES.iter().find(|r| r.id == id) else {
            eprintln!("pallas-lint: unknown rule `{id}` (see `lint --rules` for the catalog)");
            return 2;
        };
        println!("{} — {}", r.id, r.summary);
        println!("scope: {}", r.scope);
        println!();
        println!("{}", r.explain);
        return 0;
    }
    if args.flag("rules") {
        for r in pulpnn_mp::analysis::RULES {
            println!("{}  {}\n      scope: {}", r.id, r.summary, r.scope);
        }
        return 0;
    }
    if format != "text" && format != "json" {
        eprintln!("pallas-lint: --format must be text|json, got `{format}`");
        return 2;
    }
    match pulpnn_mp::analysis::lint_root(std::path::Path::new(&root)) {
        Ok(report) => {
            let active = report.diagnostics.iter().filter(|d| !d.allowed).count();
            let allowed = report.diagnostics.len() - active;
            if format == "json" {
                // pure JSONL on stdout (one object per diagnostic,
                // suppressed ones included with allowed=true); the
                // human summary goes to stderr
                for d in &report.diagnostics {
                    println!("{}", d.to_json());
                }
                eprintln!(
                    "pallas-lint: {} files scanned, {} diagnostics ({} allowed)",
                    report.files_scanned, active, allowed
                );
            } else {
                for d in report.diagnostics.iter().filter(|d| !d.allowed) {
                    println!("{d}");
                }
                println!(
                    "pallas-lint: {} files scanned, {} diagnostics ({} allowed)",
                    report.files_scanned, active, allowed
                );
            }
            if deny && active > 0 {
                1
            } else {
                0
            }
        }
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            2
        }
    }
}

fn cmd_run(args: &mut Args, seed: u64) -> i32 {
    let cores = args.opt_usize("cores", 8);
    let spec_file = args.opt_maybe("spec");
    let net = match spec_file {
        Some(path) => match pulpnn_mp::qnn::network::load_network(&path) {
            Ok(n) => n,
            Err(e) => {
                eprintln!("error loading {path}: {e}");
                return 1;
            }
        },
        None => demo_cnn().materialize().unwrap(),
    };
    let mut rng = Rng::new(seed);
    let x = QTensor::random(&mut rng, net.spec.input, net.spec.input_bits);
    let backend = GapBackend { cores, banks: 16 };
    let run = backend.run(&net, &x);
    let golden = net.forward_golden(&x);
    println!("network `{}` on simulated GAP-8 ({cores} cores):\n", net.spec.name);
    let mut t = Table::new(vec!["layer", "kind", "cycles", "MACs", "MACs/cyc"]);
    for l in &run.layers {
        t.row(vec![
            l.name.clone(),
            l.kind.to_string(),
            l.cycles.to_string(),
            l.macs.to_string(),
            f(l.macs as f64 / l.cycles.max(1) as f64, 2),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\ntotal: {} cycles, {} MACs, {} MACs/cycle",
        run.total_cycles,
        run.total_macs,
        f(run.macs_per_cycle(), 2)
    );
    println!(
        "latency: {} ms (LP @90MHz) / {} ms (HP @175MHz); energy {} uJ (LP) / {} uJ (HP)",
        f(GAP8_LP.time_ms(run.total_cycles), 2),
        f(GAP8_HP.time_ms(run.total_cycles), 2),
        f(GAP8_LP.energy_uj(run.total_cycles), 1),
        f(GAP8_HP.energy_uj(run.total_cycles), 1),
    );
    match (&run.logits, &golden.logits) {
        (Some(a), Some(b)) if a == b => {
            println!("logits match the golden model bit-exactly: {a:?}");
            0
        }
        (Some(a), Some(b)) => {
            eprintln!("LOGIT MISMATCH!\n sim:    {a:?}\n golden: {b:?}");
            1
        }
        _ => 0,
    }
}

fn cmd_footprint() -> i32 {
    use pulpnn_mp::qnn::footprint::*;
    let inv = mobilenet_v1_inventory();
    let mut t = Table::new(vec![
        "assignment", "weights [KiB]", "peak act [KiB]", "vs int-32",
    ]);
    let base = footprint_report(&inv, Assignment::UniformBits(32));
    for (label, a) in [
        ("int-32 baseline", Assignment::UniformBits(32)),
        ("uniform INT8", Assignment::UniformBits(8)),
        ("uniform INT4", Assignment::UniformBits(4)),
        ("mixed (CMix-NN style)", Assignment::MixedCmix),
    ] {
        let r = footprint_report(&inv, a);
        t.row(vec![
            label.to_string(),
            f(r.weight_bytes as f64 / 1024.0, 0),
            f(r.peak_activation_bytes as f64 / 1024.0, 0),
            format!("{}x", f(base.weight_bytes as f64 / r.weight_bytes as f64, 1)),
        ]);
    }
    println!(
        "MobileNetV1 1.0/224 footprint under precision assignments\n\
         (paper/CMix-NN claim: ~7x reduction vs int-32 with ~4% accuracy loss)\n"
    );
    print!("{}", t.render());
    0
}

fn cmd_infer(args: &mut Args) -> i32 {
    let dir = args.opt("artifacts", "artifacts");
    let name = args.opt("name", "demo_cnn_mixed");
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let Some(a) = manifest.find(&name) else {
        eprintln!(
            "artifact `{name}` not found; available: {:?}",
            manifest.artifacts.iter().map(|a| &a.name).collect::<Vec<_>>()
        );
        return 1;
    };
    let mut rt = Runtime::cpu().expect("artifact runtime");
    println!("platform: {}", rt.platform());
    // pallas-lint: allow(D003, reason = "CLI reporting: compile time of the real artifact runtime")
    let t0 = std::time::Instant::now();
    rt.load(a).expect("compile");
    println!("compiled `{}` in {:.1} ms", a.name, t0.elapsed().as_secs_f64() * 1e3);
    // pallas-lint: allow(D003, reason = "CLI reporting: execution time of the real artifact runtime")
    let t0 = std::time::Instant::now();
    let out = rt.execute_recorded(a).expect("execute");
    println!("executed in {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);
    match out {
        pulpnn_mp::runtime::ExecOutput::LogitsI32(v) => println!("logits: {v:?}"),
        pulpnn_mp::runtime::ExecOutput::PackedU8(v) => {
            println!("packed output: {} bytes, head: {:?}", v.len(), &v[..16.min(v.len())])
        }
    }
    0
}

fn cmd_verify(args: &mut Args) -> i32 {
    let dir = args.opt("artifacts", "artifacts");
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let mut rt = Runtime::cpu().expect("artifact runtime");
    let mut t = Table::new(vec!["artifact", "runtime==golden", "rust==golden", "kernels==golden"]);
    let mut failures = 0;
    for a in &manifest.artifacts {
        match verify_artifact(&mut rt, a) {
            Ok(r) => {
                if !r.ok() {
                    failures += 1;
                }
                let opt =
                    |o: Option<bool>| o.map(|b| b.to_string()).unwrap_or_else(|| "-".into());
                t.row(vec![
                    r.name.clone(),
                    r.runtime_matches_golden.to_string(),
                    opt(r.rust_matches_golden),
                    opt(r.kernel_matches_golden),
                ]);
            }
            Err(e) => {
                failures += 1;
                t.row(vec![a.name.clone(), format!("ERROR: {e}"), "-".into(), "-".into()]);
            }
        }
    }
    print!("{}", t.render());
    if failures == 0 {
        println!("\nall {} artifacts verified bit-exact across layers", manifest.artifacts.len());
        0
    } else {
        eprintln!("\n{failures} artifact(s) FAILED verification");
        1
    }
}

fn cmd_serve(args: &mut Args, seed: u64) -> i32 {
    let devices = args.opt_usize("devices", 4);
    let rate = args.opt_f64("rate", 200.0);
    let n = args.opt_usize("requests", 2000);
    let deadline_ms = args.opt_f64("deadline-ms", 0.0);
    let queue_bound = args.opt_usize("queue-bound", 0); // 0 = unbounded
    let batch_max = args.opt_usize("batch", 1).max(1); // 0 would assert in with_config
    // one physical model regardless of batching, so --batch sweeps compare
    // like for like; pass --wakeup-cycles 0 for the idealized engine
    let wakeup_cycles = args.opt_u64("wakeup-cycles", DEFAULT_WAKEUP_CYCLES);
    // sharded-tier knobs (all default to the plain single-coordinator path)
    let shards = args.opt_usize("shards", 1).max(1);
    let threads = args.opt_usize("threads", 1).max(1);
    let tenants = args.opt_usize("tenants", 1).max(1);
    let repeat_ratio = args.opt_f64("repeat-ratio", 0.0);
    let cache = args.flag("cache");
    let cache_capacity = args.opt_usize("cache-capacity", 0); // 0 = unbounded
    let cache_quota = args.opt_usize("cache-quota", 0); // 0 = unbounded
    let router_us = args.opt_f64("router-us", 0.0);
    let switch_cycles =
        args.opt_u64("switch-cycles", pulpnn_mp::energy::DEFAULT_NET_SWITCH_CYCLES);
    let policy = match args.opt("policy", "energy").as_str() {
        "rr" => Policy::RoundRobin,
        "least" => Policy::LeastLoaded,
        "tenancy" => Policy::TenancyAware,
        _ => Policy::EnergyAware,
    };
    // scheduling-stack knobs
    let discipline = match args.opt("discipline", "fifo").as_str() {
        "edf" => QueueDiscipline::Edf,
        "fifo" => QueueDiscipline::Fifo,
        other => {
            eprintln!("error: --discipline expects fifo|edf, got `{other}`");
            return 2;
        }
    };
    let steal = args.flag("steal");
    // brownout (precision-adaptive serving) knobs
    let brownout = args.opt_usize("brownout", 0); // 0 = off
    let device_classes = args.opt_maybe("device-classes");
    let floors = args.opt_maybe("floors");
    // workload-source knobs
    let closed_loop = args.opt_usize("closed-loop", 0); // 0 = open loop
    let think_us = args.opt_f64("think-us", 5_000.0);
    let trace_in = args.opt_maybe("trace-in");
    let trace_out = args.opt_maybe("trace-out");
    // fault-injection knobs (all absent = the byte-identical fault-free engine)
    let mtbf_us = args.opt_f64("mtbf-us", 0.0); // 0 = no generated crashes
    let mttr_us = args.opt_f64("mttr-us", 100_000.0);
    let straggler = args.opt_f64("straggler", 1.0);
    let retry_budget = args.opt_u64("retry-budget", 3) as u32;
    let fault_trace_in = args.opt_maybe("fault-trace-in");
    let fault_trace_out = args.opt_maybe("fault-trace-out");
    // per-inference cycles from the simulated demo CNN
    let net = demo_cnn().materialize().unwrap();
    let mut rng = Rng::new(seed);
    let x = QTensor::random(&mut rng, net.spec.input, net.spec.input_bits);
    let cycles = GapBackend::default().run(&net, &x).total_cycles;
    println!(
        "demo CNN: {} cycles/inference -> {} ms on LP, {} ms on HP",
        cycles,
        f(GAP8_LP.time_ms(cycles), 2),
        f(GAP8_HP.time_ms(cycles), 2)
    );
    // half LP, half HP fleet — or an explicit heterogeneous mix, with
    // each class's inference cost scaled by its measured Reference Layer
    // anchor (fig. 5's speed gaps, not invented multipliers)
    let nodes = match &device_classes {
        Some(spec) => {
            let mut nodes = Vec::new();
            for (i, name) in spec.split(',').enumerate() {
                let Some(cls) = DeviceClass::parse(name.trim()) else {
                    eprintln!("error: --device-classes expects lp|hp|m7|l4, got `{name}`");
                    return 2;
                };
                nodes.push(Device::new(
                    format!("{}{i}", cls.short_name()),
                    cls.op(),
                    cls.scale_cycles(cycles),
                ));
            }
            nodes
        }
        None => gap8_mixed_devices(devices, cycles),
    };
    let devices = nodes.len();
    // a single-tenant workload never switches nets, so the knob is
    // harmlessly inert there (bit-exactness is regression-tested)
    let config = FleetConfig {
        queue_bound: if queue_bound == 0 { usize::MAX } else { queue_bound },
        batch_max,
        wakeup_cycles,
        net_switch_cycles: switch_cycles,
        discipline,
        steal,
        degrade: if brownout > 0 {
            DegradePolicy::Watermark { watermark: brownout }
        } else {
            DegradePolicy::Off
        },
    };
    // the brownout variant table: the measured MobileNetV1 8/4/2-bit
    // ladder, with optional per-tenant accuracy floors
    let variants: Option<VariantTable> = if brownout > 0 {
        let mut table = VariantTable::mobilenet_default();
        if let Some(spec) = &floors {
            for part in spec.split(',') {
                let parsed = part.split_once(':').and_then(|(net, q)| {
                    Some((net.trim().parse::<u32>().ok()?, q.trim().parse::<f64>().ok()?))
                });
                match parsed {
                    Some((net, q)) => table.set_floor(net, q),
                    None => {
                        eprintln!("error: --floors expects NET:MIN_QUALITY,.., got `{part}`");
                        return 2;
                    }
                }
            }
        }
        println!(
            "brownout: watermark {brownout} — queues past the watermark serve \
             a reduced-precision variant instead of shedding"
        );
        Some(table)
    } else {
        None
    };
    let deadline_us = if deadline_ms > 0.0 { Some(deadline_ms * 1e3) } else { None };
    // multi-tenant closed loops run on the single fleet (the client pool
    // spreads clients across the tenant networks); only genuine tier
    // features — shards, cache, a priced router — force the sharded path,
    // and since the unified tier event loop they compose with
    // --closed-loop (the feedback edge crosses routers and shards)
    let sharded = shards > 1 || cache || router_us > 0.0 || (tenants > 1 && closed_loop == 0);
    if closed_loop > 0 && trace_in.is_some() {
        eprintln!("error: --closed-loop and --trace-in are mutually exclusive");
        return 2;
    }
    // the arrival stream: closed loops generate their own inside the run;
    // else a replayed trace file beats generation; else one open-loop
    // Poisson stream per tenant network, merged in arrival order
    let requests: Vec<Request> = if closed_loop > 0 {
        Vec::new()
    } else if let Some(path) = &trace_in {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("error reading trace {path}: {e}");
                return 1;
            }
        };
        match TraceSource::parse_jsonl(&text) {
            Ok(src) => {
                println!("replaying trace {path}: {} requests", src.requests().len());
                src.into_requests()
            }
            Err(e) => {
                eprintln!("error parsing trace {path}: {e}");
                return 1;
            }
        }
    } else {
        merge_streams(
            &(0..tenants as u32)
                .map(|t| {
                    Workload {
                        rate_per_s: rate / tenants as f64,
                        deadline_us,
                        n_requests: n / tenants,
                        seed: seed.wrapping_add(t as u64),
                    }
                    .generate_with_repeats(t, repeat_ratio)
                })
                .collect::<Vec<_>>(),
        )
    };
    let dump_trace = |reqs: &[Request]| -> i32 {
        if let Some(path) = &trace_out {
            if let Err(e) = std::fs::write(path, TraceSource::to_jsonl(reqs)) {
                eprintln!("error writing trace {path}: {e}");
                return 1;
            }
            println!("dumped {} arrivals to {path}", reqs.len());
        }
        0
    };

    // the fault schedule: a replayed trace beats generation; generation
    // engages only when --mtbf-us is given, over the horizon of the
    // offered arrivals (closed loops generate arrivals inside the run,
    // so their horizon is estimated from the request budget and rate)
    let fault_plan: Option<FaultPlan> = if let Some(path) = &fault_trace_in {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("error reading fault trace {path}: {e}");
                return 1;
            }
        };
        match FaultPlan::parse_jsonl(&text) {
            Ok(p) => {
                println!("replaying fault trace {path}: {} events", p.events().len());
                Some(p)
            }
            Err(e) => {
                eprintln!("error parsing fault trace {path}: {e}");
                return 1;
            }
        }
    } else if mtbf_us > 0.0 {
        let horizon_us = requests
            .last()
            .map(|r| r.arrival_us)
            .unwrap_or(n as f64 * 1e6 / rate.max(1e-9));
        let p = FaultPlan::generate(
            &FaultParams { mtbf_us, mttr_us, straggler_factor: straggler, seed },
            devices,
            horizon_us,
        );
        println!(
            "fault injection: mtbf {} us / mttr {} us over {devices} device(s) \
             -> {} scheduled events (retry budget {retry_budget})",
            f(mtbf_us, 0),
            f(mttr_us, 0),
            p.events().len()
        );
        Some(p)
    } else {
        None
    };
    if let Some(path) = &fault_trace_out {
        let p = fault_plan.clone().unwrap_or_else(FaultPlan::none);
        if let Err(e) = std::fs::write(path, p.to_jsonl()) {
            eprintln!("error writing fault trace {path}: {e}");
            return 1;
        }
        println!("dumped {} fault events to {path}", p.events().len());
    }
    let retry = RetryPolicy { budget: retry_budget, ..RetryPolicy::default() };

    if !sharded {
        let mut fleet = Fleet::with_config(nodes, policy, config);
        if let Some(table) = variants.clone() {
            fleet.set_variants(table);
        }
        if let Some(plan) = &fault_plan {
            fleet.set_faults(plan.clone(), retry);
        }
        let (report, offered) = if closed_loop > 0 {
            let mut src = ClosedLoopSource::new(closed_loop, think_us, n, seed)
                .with_nets(tenants as u32);
            if let Some(dl) = deadline_us {
                src = src.with_deadline(dl);
            }
            println!(
                "closed loop: {closed_loop} client(s), {} us mean think time, {n} request budget",
                f(think_us, 0)
            );
            let (report, injected) = fleet.run_source_traced(&mut src);
            let rc = dump_trace(&injected);
            if rc != 0 {
                return rc;
            }
            (report, injected.len())
        } else {
            let rc = dump_trace(&requests);
            if rc != 0 {
                return rc;
            }
            (fleet.run(&requests), requests.len())
        };
        println!(
            "\nfleet of {devices} ({policy:?}, {discipline:?}, queue_bound={}, \
             batch_max={batch_max}, steal {}), {} of {offered} requests served:",
            if queue_bound == 0 { "inf".to_string() } else { queue_bound.to_string() },
            if steal { "on" } else { "off" },
            report.completions.len(),
        );
        println!("  throughput     : {} rps", f(report.throughput_rps, 1));
        println!("  mean latency   : {} ms", f(report.mean_latency_us / 1e3, 2));
        println!("  p99 latency    : {} ms", f(report.p99_latency_us / 1e3, 2));
        println!(
            "  energy         : {} mJ active + {} mJ idle",
            f(report.active_energy_uj / 1e3, 2),
            f(report.idle_energy_uj / 1e3, 2)
        );
        println!("  deadline misses: {}", report.deadline_misses);
        println!("  shed requests  : {}", report.shed);
        if fault_plan.is_some() {
            println!(
                "  faults         : {} crash(es), {} retry(ies), {} failed",
                report.faults,
                report.retries,
                report.failures.len()
            );
            if !report.recovery_us.is_empty() {
                println!(
                    "  recovery       : p50 {} / p95 {} / p99 {} ms",
                    f(percentile(&report.recovery_us, 50.0) / 1e3, 2),
                    f(percentile(&report.recovery_us, 95.0) / 1e3, 2),
                    f(percentile(&report.recovery_us, 99.0) / 1e3, 2)
                );
            }
        }
        if brownout > 0 {
            println!("  degraded       : {}", report.degraded);
            println!("  quality goodput: {} rps", f(report.quality_weighted_goodput, 1));
        }
        println!(
            "  activations    : {} ({} requests/batch mean)",
            report.batches,
            f(report.mean_batch_size, 2)
        );
        println!("  work steals    : {}", report.steals);
        println!("  per-device     : {:?}", report.per_device_served);
        println!(
            "  utilization    : {:?}",
            report.per_device_utilization.iter().map(|u| f(*u, 2)).collect::<Vec<_>>()
        );
        return 0;
    }

    if devices < shards {
        eprintln!("error: need at least one device per shard (--devices {devices} < --shards {shards})");
        return 2;
    }
    let shard_config = ShardConfig {
        shards,
        router_service_us: router_us,
        tenancy_aware_routing: tenants > 1,
        cache,
        cache_capacity: if cache_capacity == 0 { usize::MAX } else { cache_capacity },
        cache_quota_per_net: if cache_quota == 0 { usize::MAX } else { cache_quota },
        exec: if threads > 1 {
            ExecMode::Parallel { threads }
        } else {
            ExecMode::SingleThread
        },
    };
    if threads > 1 {
        println!(
            "parallel: {threads} worker thread(s) advance the {shards} shard \
             engine(s) inside conservative lookahead windows (bit-identical \
             to --threads 1)"
        );
    }
    let mut tier = ShardedFleet::new(nodes, policy, config, shard_config);
    if let Some(table) = variants.clone() {
        tier.set_variants(table);
    }
    if let Some(plan) = &fault_plan {
        tier.set_faults(plan.clone(), retry);
    }
    let (report, offered) = if closed_loop > 0 {
        // the unified tier event loop closes the feedback edge across
        // routers, shards and the result cache, so the client pool
        // drives the whole tier directly
        let mut src =
            ClosedLoopSource::new(closed_loop, think_us, n, seed).with_nets(tenants as u32);
        if let Some(dl) = deadline_us {
            src = src.with_deadline(dl);
        }
        println!(
            "closed loop: {closed_loop} client(s), {} us mean think time, {n} request budget",
            f(think_us, 0)
        );
        match tier.run_source_traced(&mut src) {
            Ok((report, injected)) => {
                let rc = dump_trace(&injected);
                if rc != 0 {
                    return rc;
                }
                (report, injected.len())
            }
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    } else {
        let rc = dump_trace(&requests);
        if rc != 0 {
            return rc;
        }
        (tier.run(&requests), requests.len())
    };
    if let Err(e) = report.check_conservation(offered) {
        eprintln!("BUG: {e}");
        return 1;
    }
    println!(
        "\nsharded tier: {shards} shard(s) x {} device(s), {tenants} tenant(s), \
         {policy:?}, {discipline:?}, steal {}, cache {}:",
        devices / shards,
        if steal { "on" } else { "off" },
        if cache { "on" } else { "off" }
    );
    println!(
        "  completed      : {} of {offered} ({} shed, {} failed)",
        report.total_completed, report.total_shed, report.total_failed
    );
    if fault_plan.is_some() {
        println!(
            "  faults         : {} crash(es), {} retry(ies), {} failed",
            report.faults, report.retries, report.total_failed
        );
        for (w, (p50, p95, p99)) in report.recovery_percentiles.iter().enumerate() {
            println!(
                "  recovery w{w}    : p50 {} / p95 {} / p99 {} ms",
                f(p50 / 1e3, 2),
                f(p95 / 1e3, 2),
                f(p99 / 1e3, 2)
            );
        }
    }
    println!("  throughput     : {} rps", f(report.throughput_rps, 1));
    if brownout > 0 {
        println!("  degraded       : {}", report.degraded);
        println!("  quality goodput: {} rps", f(report.quality_weighted_goodput, 1));
    }
    println!("  service latency: {} ms mean", f(report.mean_service_latency_us / 1e3, 2));
    println!("  router wait    : {} ms mean", f(report.mean_router_delay_us / 1e3, 3));
    println!("  deadline misses: {}", report.deadline_misses);
    println!(
        "  energy         : {} mJ active + {} mJ idle",
        f(report.active_energy_uj / 1e3, 2),
        f(report.idle_energy_uj / 1e3, 2)
    );
    println!(
        "  residency      : {} net-switches ({} mJ)",
        report.net_switches,
        f(report.switch_energy_uj / 1e3, 3)
    );
    if cache {
        println!(
            "  result cache   : {}/{} hits ({}%), ~{} mJ device energy saved",
            report.cache.hits,
            report.cache.lookups,
            f(report.cache.hit_rate * 100.0, 1),
            f(report.cache.energy_saved_uj / 1e3, 2)
        );
        println!(
            "  cache bounds   : {} resident entries, {} evictions",
            report.cache.entries, report.cache.evictions
        );
    }
    println!("  work steals    : {}", report.steals);
    println!(
        "  shard balance  : routed {:?}, utilization skew {}",
        report.per_shard_routed,
        f(report.utilization_skew, 3)
    );
    println!(
        "  queue depth    : p50 {} / p95 {} / p99 {}",
        f(report.queue_depth_p50, 1),
        f(report.queue_depth_p95, 1),
        f(report.queue_depth_p99, 1)
    );
    0
}
