//! Platform power/energy models (Fig. 6).
//!
//! Energy = cycles / frequency x active power. The power figures are
//! datasheet/publication values for the paper's exact parts:
//!
//! * GAP-8 (GreenWaves, 55 nm): the ASAP'18 paper reports ~4.5 mW/100 MHz
//!   per-core-cluster scaling; the octa-core cluster draws ~24 mW at the
//!   1.0 V / 90 MHz low-power point and ~70 mW at 1.2 V / 175 MHz
//!   high-performance point.
//! * STM32H743 (40 nm): ~585 uA/MHz at VOS1 from the datasheet — ~234 mW
//!   at 400 MHz (the paper's "higher frequency" H7 operating point).
//! * STM32L476 (90 nm ULP): ~120 uA/MHz run mode — ~10 mW at 80 MHz.

/// One platform operating point.
///
/// `power_mw` is the active (cluster-computing) power; `idle_power_mw` is
/// the power drawn while a device sits in the serving loop with the
/// compute cluster power-gated, waiting for work (order-of-magnitude
/// datasheet sleep/retention figures — the fleet simulator charges it for
/// queue-empty gaps between activations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    /// Human-readable platform/mode name.
    pub name: &'static str,
    /// Cluster clock frequency in MHz.
    pub freq_mhz: f64,
    /// Active (cluster-computing) power in mW.
    pub power_mw: f64,
    /// Power drawn while idling with the cluster power-gated, in mW.
    pub idle_power_mw: f64,
}

/// Default cycle cost of a *weight-residency switch*: evicting the resident
/// network's weights from cluster memory and DMA-loading another network's
/// set from L2 before an activation can serve it.
///
/// Sized for a demo-CNN-scale mixed-precision weight set (~100 KiB packed):
/// the cluster DMA moves ~2 B/cycle effective once L2 contention and
/// per-transfer setup are accounted for, giving ~50k cycles (~0.56 ms at
/// the 90 MHz low-power point) — a sixth of a demo-CNN inference, which is
/// why tenancy-aware routing that avoids switches pays off. Charged by the
/// fleet engine via [`FleetConfig::net_switch_cycles`]; the energy cost is
/// the same cycles through [`OperatingPoint::energy_uj`] (the DMA runs at
/// cluster active power).
///
/// [`FleetConfig::net_switch_cycles`]: crate::coordinator::FleetConfig::net_switch_cycles
pub const DEFAULT_NET_SWITCH_CYCLES: u64 = 50_000;

/// GAP-8 low-power mode: 1.0 V, 90 MHz cluster.
pub const GAP8_LP: OperatingPoint =
    OperatingPoint { name: "GAP-8 (low-power)", freq_mhz: 90.0, power_mw: 24.0, idle_power_mw: 1.0 };

/// GAP-8 high-performance mode: 1.2 V, 175 MHz cluster.
pub const GAP8_HP: OperatingPoint =
    OperatingPoint { name: "GAP-8 (high-perf)", freq_mhz: 175.0, power_mw: 70.0, idle_power_mw: 2.0 };

/// STM32H743 at 400 MHz, VOS1.
pub const STM32H7_OP: OperatingPoint =
    OperatingPoint { name: "STM32H7", freq_mhz: 400.0, power_mw: 234.0, idle_power_mw: 20.0 };

/// STM32L476 at 80 MHz run mode.
pub const STM32L4_OP: OperatingPoint =
    OperatingPoint { name: "STM32L4", freq_mhz: 80.0, power_mw: 10.0, idle_power_mw: 1.0 };

/// Measured 8-bit Reference Layer cycle anchor for the GAP-8 8-core
/// cluster: ~16 MACs/cycle over 4.72 MMAC -> ~295k cycles (paper Fig. 5).
pub const GAP8_REFERENCE_CYCLES: u64 = 295_000;
/// Measured 8-bit Reference Layer cycle anchor for the STM32H7 (Cortex-M7,
/// ~0.64 MACs/cycle -> ~7.37M cycles; the paper's 21-25x speed gap).
pub const STM32H7_REFERENCE_CYCLES: u64 = 7_370_000;
/// Measured 8-bit Reference Layer cycle anchor for the STM32L4 (Cortex-M4,
/// ~0.35 MACs/cycle -> ~13.5M cycles).
pub const STM32L4_REFERENCE_CYCLES: u64 = 13_500_000;

/// A hardware class a fleet device can belong to: an [`OperatingPoint`]
/// (power/frequency) paired with the class's measured Reference Layer
/// cycle anchor, so heterogeneous fleets derive per-class inference cost
/// from the paper's measured speed gaps instead of invented multipliers.
///
/// A device of class `c` serving a net whose GAP-8 cost is `base` cycles
/// is charged `base * c.reference_cycles() / GAP8_REFERENCE_CYCLES`
/// cycles at its own clock — e.g. an M7-class device runs the same net
/// ~25x more cycles than a GAP-8-class one, exactly the paper's gap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    /// GAP-8 cluster at the 1.0 V / 90 MHz low-power point.
    Gap8Lp,
    /// GAP-8 cluster at the 1.2 V / 175 MHz high-performance point.
    Gap8Hp,
    /// STM32H743 (Cortex-M7) at 400 MHz.
    M7,
    /// STM32L476 (Cortex-M4) at 80 MHz.
    L4,
}

impl DeviceClass {
    /// All classes, in descending per-cycle capability order.
    pub const ALL: [DeviceClass; 4] =
        [DeviceClass::Gap8Hp, DeviceClass::Gap8Lp, DeviceClass::M7, DeviceClass::L4];

    /// The class's power/frequency operating point.
    pub fn op(self) -> OperatingPoint {
        match self {
            DeviceClass::Gap8Lp => GAP8_LP,
            DeviceClass::Gap8Hp => GAP8_HP,
            DeviceClass::M7 => STM32H7_OP,
            DeviceClass::L4 => STM32L4_OP,
        }
    }

    /// Measured 8-bit Reference Layer cycles on this class (the per-class
    /// speed anchor; GAP-8 modes share the cluster's cycle count and
    /// differ only in clock/power).
    pub fn reference_cycles(self) -> u64 {
        match self {
            DeviceClass::Gap8Lp | DeviceClass::Gap8Hp => GAP8_REFERENCE_CYCLES,
            DeviceClass::M7 => STM32H7_REFERENCE_CYCLES,
            DeviceClass::L4 => STM32L4_REFERENCE_CYCLES,
        }
    }

    /// Scale a GAP-8-denominated cycle count to this class via the
    /// measured anchors (exact integer arithmetic, round-down).
    pub fn scale_cycles(self, gap8_cycles: u64) -> u64 {
        let widened = gap8_cycles as u128 * self.reference_cycles() as u128;
        (widened / GAP8_REFERENCE_CYCLES as u128) as u64
    }

    /// Parse a short class name as used by `serve --device-classes`.
    pub fn parse(s: &str) -> Option<DeviceClass> {
        match s {
            "lp" | "gap8-lp" => Some(DeviceClass::Gap8Lp),
            "hp" | "gap8-hp" => Some(DeviceClass::Gap8Hp),
            "m7" | "h7" => Some(DeviceClass::M7),
            "l4" | "m4" => Some(DeviceClass::L4),
            _ => None,
        }
    }

    /// Short name (the `parse` canonical spelling).
    pub fn short_name(self) -> &'static str {
        match self {
            DeviceClass::Gap8Lp => "lp",
            DeviceClass::Gap8Hp => "hp",
            DeviceClass::M7 => "m7",
            DeviceClass::L4 => "l4",
        }
    }
}

impl OperatingPoint {
    /// Execution time for a cycle count, in milliseconds.
    pub fn time_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e3)
    }

    /// Execution time for a cycle count, in microseconds (the fleet
    /// simulator's native unit).
    pub fn time_us(&self, cycles: u64) -> f64 {
        self.time_ms(cycles) * 1e3
    }

    /// Energy for a cycle count, in microjoules.
    pub fn energy_uj(&self, cycles: u64) -> f64 {
        self.time_ms(cycles) * self.power_mw
    }

    /// Energy spent idling (cluster power-gated) for a wall-clock span in
    /// microseconds, in microjoules: mW x ms = uJ.
    pub fn idle_energy_uj(&self, idle_us: f64) -> f64 {
        (idle_us / 1e3) * self.idle_power_mw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_scales_linearly_with_cycles() {
        let e1 = GAP8_LP.energy_uj(90_000);
        let e2 = GAP8_LP.energy_uj(180_000);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
        // 90k cycles at 90 MHz = 1 ms at 24 mW = 24 uJ
        assert!((e1 - 24.0).abs() < 1e-9);
    }

    #[test]
    fn paper_energy_ratio_anchors() {
        // 8-bit Reference Layer: GAP-8 8-core ~ 16 MACs/cycle -> ~295k
        // cycles for 4.72 MMAC; H7 ~ 0.64 -> 7.37M cycles; L4 ~ 0.35 ->
        // 13.5M cycles. The paper reports 45x/21x (LP) and 31x/15x (HP).
        let gap_cycles = GAP8_REFERENCE_CYCLES;
        let h7_cycles = STM32H7_REFERENCE_CYCLES;
        let l4_cycles = STM32L4_REFERENCE_CYCLES;
        let lp = GAP8_LP.energy_uj(gap_cycles);
        let hp = GAP8_HP.energy_uj(gap_cycles);
        let h7 = STM32H7_OP.energy_uj(h7_cycles);
        let l4 = STM32L4_OP.energy_uj(l4_cycles);
        let r_h7_lp = h7 / lp;
        let r_l4_lp = l4 / lp;
        let r_h7_hp = h7 / hp;
        let r_l4_hp = l4 / hp;
        assert!((35.0..70.0).contains(&r_h7_lp), "H7/LP {r_h7_lp} (paper 45x)");
        assert!((15.0..30.0).contains(&r_l4_lp), "L4/LP {r_l4_lp} (paper 21x)");
        assert!((20.0..45.0).contains(&r_h7_hp), "H7/HP {r_h7_hp} (paper 31x)");
        assert!((8.0..22.0).contains(&r_l4_hp), "L4/HP {r_l4_hp} (paper 15x)");
    }

    #[test]
    fn time_us_is_time_ms_scaled() {
        assert!((GAP8_LP.time_us(90_000) - 1000.0).abs() < 1e-9);
        assert!((GAP8_LP.time_us(90_000) - GAP8_LP.time_ms(90_000) * 1e3).abs() < 1e-12);
    }

    #[test]
    fn net_switch_cost_is_a_fraction_of_an_inference() {
        // A residency switch must cost well under a demo-CNN inference
        // (~300k cycles) or tenancy-aware routing could never pay off.
        assert!(DEFAULT_NET_SWITCH_CYCLES < 300_000 / 2);
        assert!(DEFAULT_NET_SWITCH_CYCLES > 0);
        // ~0.56 ms / ~13 uJ at the LP point
        assert!((GAP8_LP.time_ms(DEFAULT_NET_SWITCH_CYCLES) - 0.5556).abs() < 1e-3);
    }

    #[test]
    fn device_class_scaling_reproduces_paper_speed_gaps() {
        // scale_cycles is anchored on the measured Reference Layer runs:
        // GAP-8 classes are identity; M7 is ~25x, L4 ~46x more cycles.
        assert_eq!(DeviceClass::Gap8Hp.scale_cycles(300_000), 300_000);
        assert_eq!(DeviceClass::Gap8Lp.scale_cycles(300_000), 300_000);
        let m7 = DeviceClass::M7.scale_cycles(300_000) as f64 / 300_000.0;
        let l4 = DeviceClass::L4.scale_cycles(300_000) as f64 / 300_000.0;
        assert!((21.0..28.0).contains(&m7), "M7 factor {m7} (paper 21-25x)");
        assert!((40.0..50.0).contains(&l4), "L4 factor {l4}");
        // wall-clock: an M7 at 400 MHz still loses to GAP-8 HP at 175 MHz
        let gap_us = GAP8_HP.time_us(300_000);
        let m7_us = STM32H7_OP.time_us(DeviceClass::M7.scale_cycles(300_000));
        assert!(m7_us / gap_us > 8.0, "paper's wall-clock gap: {}", m7_us / gap_us);
    }

    #[test]
    fn device_class_parse_round_trips() {
        for c in DeviceClass::ALL {
            assert_eq!(DeviceClass::parse(c.short_name()), Some(c));
            assert!(c.op().freq_mhz > 0.0);
        }
        assert_eq!(DeviceClass::parse("tpu"), None);
    }

    #[test]
    fn gap8_low_power_is_most_efficient_point() {
        // same cycle count: LP must beat HP in energy (lower V/f)
        assert!(GAP8_LP.energy_uj(1000) < GAP8_HP.energy_uj(1000));
    }

    #[test]
    fn idle_power_is_far_below_active() {
        for op in [GAP8_LP, GAP8_HP, STM32H7_OP, STM32L4_OP] {
            assert!(op.idle_power_mw < op.power_mw / 5.0, "{}", op.name);
        }
        // 1 ms idle on GAP-8 LP at 1 mW = 1 uJ
        assert!((GAP8_LP.idle_energy_uj(1000.0) - 1.0).abs() < 1e-12);
    }
}
