//! ASCII table and bar-chart rendering for the paper-figure harness.
//!
//! The paper's evaluation is two bar charts (Fig. 4, Fig. 5), one grouped
//! bar chart (Fig. 6) and one table (Tab. 1). `pulpnn figN` renders the same
//! rows/series as text so the reproduction can be eyeballed against the
//! paper in a terminal and diffed in CI.

/// A simple right-padded text table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}", c, w = widths[i]));
                line.push_str(" | ");
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Horizontal bar chart: one `#`-bar per labelled value, scaled to `width`.
pub fn bar_chart(title: &str, entries: &[(String, f64)], width: usize) -> String {
    let max = entries.iter().map(|e| e.1).fold(f64::MIN, f64::max).max(1e-12);
    let label_w = entries.iter().map(|e| e.0.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in entries {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!("  {label:<label_w$} | {:<width$} {v:.2}\n", "#".repeat(n)));
    }
    out
}

/// Format a f64 with a fixed number of decimals (helper for table cells).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["short", "1"]);
        t.row(vec!["a-longer-name", "23456"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len() || l.starts_with('|')));
        assert!(s.contains("a-longer-name"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart("demo", &[("a".into(), 1.0), ("b".into(), 2.0)], 10);
        assert!(s.contains("##########"));
        assert!(s.contains("#####"));
    }

    #[test]
    fn fixed_decimals() {
        assert_eq!(f(2.4567, 2), "2.46");
    }
}
