//! Minimal JSON parser and writer.
//!
//! The artifact manifests written by `python/compile/aot.py` and the network
//! spec files are JSON; serde is unavailable offline, so this module
//! implements the subset of JSON we need (full JSON minus `\u` surrogate
//! pairs in strings, which never appear in our manifests — plain `\uXXXX`
//! escapes are supported).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers keep both integer and floating views where
/// applicable (`I64` used whenever the literal is integral and in range).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    I64(i64),
    F64(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::I64(v) => Some(*v),
            Json::F64(v) if v.fract() == 0.0 => Some(*v as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::I64(v) => Some(*v as f64),
            Json::F64(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns `Json::Null` for missing keys so lookups
    /// compose (`j.get("a").get("b")`).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index access with the same Null-propagation convention.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Required typed accessors for manifest parsing (error over panic).
    pub fn req_i64(&self, key: &str) -> Result<i64, String> {
        self.get(key).as_i64().ok_or_else(|| format!("missing int field `{key}`"))
    }
    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        self.get(key).as_usize().ok_or_else(|| format!("missing uint field `{key}`"))
    }
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key).as_str().ok_or_else(|| format!("missing string field `{key}`"))
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Json], String> {
        self.get(key).as_arr().ok_or_else(|| format!("missing array field `{key}`"))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos.saturating_sub(1)))
        }
    }
    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("truncated \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad \\u escape")?;
                        }
                        out.push(char::from_u32(code).ok_or("surrogate \\u escape unsupported")?);
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let ch = s.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    out.push(ch);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>().map(Json::F64).map_err(|e| e.to_string())
        } else {
            match text.parse::<i64>() {
                Ok(v) => Ok(Json::I64(v)),
                Err(_) => text.parse::<f64>().map(Json::F64).map_err(|e| e.to_string()),
            }
        }
    }
}

/// Serialize with stable key order (BTreeMap) — deterministic output.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::I64(v) => write!(f, "{v}"),
            Json::F64(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    write!(f, "{v:.1}")
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::I64(-42));
        assert_eq!(Json::parse("2.5").unwrap(), Json::F64(2.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::F64(1000.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").at(0).as_i64(), Some(1));
        assert_eq!(j.get("a").at(1).get("b").as_bool(), Some(false));
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert_eq!(j.get("missing").as_str(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::I64(rng.range_i64(-1_000_000, 1_000_000)),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_roundtrip() {
        check("json-roundtrip", 200, |rng, _| {
            let v = random_json(rng, 3);
            let text = v.to_string();
            let back = Json::parse(&text).map_err(|e| format!("reparse failed: {e} for {text}"))?;
            if back != v {
                return Err(format!("roundtrip mismatch: {v:?} -> {text} -> {back:?}"));
            }
            Ok(())
        });
    }
}
