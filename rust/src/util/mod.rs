//! Shared utilities: deterministic RNG, property-test harness, JSON,
//! statistics, table rendering, CLI parsing and the micro-bench harness.
//!
//! Everything here is dependency-free (std only) because the build
//! environment is offline — see DESIGN.md §6.

pub mod benchkit;
pub mod check;
pub mod cli;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
