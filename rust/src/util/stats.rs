//! Small statistics helpers used by the benchmark harness and the paper
//! tables (mean / variance / min / max / percentiles over cycle samples).

/// Summary statistics over a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, stddev: var.sqrt(), min, max }
    }

    /// Half-width of the min..max spread — what the paper's Table 1 reports
    /// as "+/- variance" (a spread band, not a statistical variance).
    pub fn spread(&self) -> f64 {
        (self.max - self.min) / 2.0
    }
}

/// Percentile by nearest-rank on a sorted copy (p in [0,100]). The sort
/// is a total order (`f64::total_cmp`): NaN inputs rank last instead of
/// panicking, so report paths stay NaN-safe.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.stddev - 1.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.spread(), 1.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.spread(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0); // rank round(1.5)=2 -> 3.0
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }
}
