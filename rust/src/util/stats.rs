//! Small statistics helpers used by the benchmark harness and the paper
//! tables (mean / variance / min / max / percentiles over cycle samples).

/// Summary statistics over a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, stddev: var.sqrt(), min, max }
    }

    /// Half-width of the min..max spread — what the paper's Table 1 reports
    /// as "+/- variance" (a spread band, not a statistical variance).
    pub fn spread(&self) -> f64 {
        (self.max - self.min) / 2.0
    }
}

/// Percentile by nearest-rank on a sorted copy (p in [0,100]). The sort
/// is a total order (`f64::total_cmp`): NaN inputs rank last instead of
/// panicking, so report paths stay NaN-safe.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Streaming percentile tracker over fixed-size windows.
///
/// Long-running serve/bench loops want p50/p95/p99 without retaining the
/// whole sample history. `push` fills a fixed ring; each time the window
/// fills, its percentiles (nearest-rank via [`percentile`], so
/// `total_cmp` NaN-safety carries over) are folded into running window
/// summaries. `flush` reports any partial tail window so no sample is
/// silently dropped.
#[derive(Debug, Clone)]
pub struct WindowedPercentiles {
    window: Vec<f64>,
    capacity: usize,
    /// (p50, p95, p99) of each completed window, in arrival order.
    pub windows: Vec<(f64, f64, f64)>,
}

impl WindowedPercentiles {
    pub fn new(capacity: usize) -> WindowedPercentiles {
        assert!(capacity > 0, "WindowedPercentiles::new(0)");
        WindowedPercentiles { window: Vec::with_capacity(capacity), capacity, windows: Vec::new() }
    }

    /// Add a sample; closes and summarizes the window when it fills.
    pub fn push(&mut self, x: f64) {
        self.window.push(x);
        if self.window.len() == self.capacity {
            self.close_window();
        }
    }

    /// Close a partial tail window, if any, then return the per-window
    /// summaries in arrival order.
    pub fn flush(&mut self) -> &[(f64, f64, f64)] {
        if !self.window.is_empty() {
            self.close_window();
        }
        &self.windows
    }

    /// Number of samples in the currently open (unreported) window.
    pub fn pending(&self) -> usize {
        self.window.len()
    }

    fn close_window(&mut self) {
        let w = &self.window;
        self.windows.push((percentile(w, 50.0), percentile(w, 95.0), percentile(w, 99.0)));
        self.window.clear();
    }
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.stddev - 1.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.spread(), 1.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.spread(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0); // rank round(1.5)=2 -> 3.0
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn windowed_percentiles_match_the_batch_percentile_fn() {
        let samples: Vec<f64> = (0..25).map(|i| ((i * 7) % 25) as f64).collect();
        let mut wp = WindowedPercentiles::new(10);
        for &x in &samples {
            wp.push(x);
        }
        assert_eq!(wp.pending(), 5, "25 samples over windows of 10 leave a 5-sample tail");
        let windows = wp.flush().to_vec();
        assert_eq!(windows.len(), 3);
        for (i, chunk) in samples.chunks(10).enumerate() {
            let expect =
                (percentile(chunk, 50.0), percentile(chunk, 95.0), percentile(chunk, 99.0));
            assert_eq!(windows[i], expect, "window {i} disagrees with the batch percentile fn");
        }
    }

    #[test]
    fn windowed_percentiles_flush_is_idempotent_and_nan_safe() {
        let mut wp = WindowedPercentiles::new(4);
        for x in [1.0, f64::NAN, 2.0] {
            wp.push(x);
        }
        let first = wp.flush().to_vec();
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].0, 2.0, "NaN ranks last under total_cmp, so p50 of 3 is 2.0");
        assert_eq!(wp.pending(), 0);
        assert_eq!(wp.flush().len(), 1, "flushing with nothing pending adds no window");
    }

    #[test]
    fn windowed_percentiles_exact_fill_leaves_no_tail() {
        let mut wp = WindowedPercentiles::new(3);
        for x in [3.0, 1.0, 2.0, 9.0, 7.0, 8.0] {
            wp.push(x);
        }
        assert_eq!(wp.pending(), 0);
        assert_eq!(wp.flush(), &[(2.0, 3.0, 3.0), (8.0, 9.0, 9.0)]);
    }
}
