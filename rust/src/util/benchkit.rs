//! Hand-rolled micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage in a `[[bench]]` target with `harness = false`:
//! ```ignore
//! let mut b = Bench::new("matmul_hot");
//! b.run("w8/x8", || { ... });
//! b.report();
//! ```
//! Each case is warmed up, then timed over adaptively-chosen batch sizes
//! until a wall-clock budget is used; mean / stddev / min per-iteration
//! times are reported.
//!
//! When the `PULPNN_BENCH_JSON` environment variable names a directory,
//! [`Bench::report`] additionally writes `BENCH_<group>.json` there — the
//! machine-readable perf trajectory (`pulpnn-bench-v1` schema, documented
//! in `docs/BENCHMARKS.md`).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

pub struct CaseResult {
    pub name: String,
    pub iters: u64,
    pub per_iter: Summary,
    /// Optional throughput annotation: (units, amount per iteration).
    pub throughput: Option<(String, f64)>,
}

pub struct Bench {
    pub group: String,
    budget: Duration,
    results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // PULPNN_BENCH_BUDGET_MS shrinks runs in CI/tests.
        let ms = std::env::var("PULPNN_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(400u64);
        Bench { group: group.to_string(), budget: Duration::from_millis(ms), results: Vec::new() }
    }

    /// Time `f`, which performs one logical iteration and returns a value
    /// that is passed through `std::hint::black_box` to defeat DCE.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, f: F) {
        self.run_with_throughput(name, None, f)
    }

    /// Like [`run`], annotating each iteration with a throughput amount
    /// (e.g. simulated MACs) so the report shows units/second.
    pub fn run_with_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        throughput: Option<(String, f64)>,
        mut f: F,
    ) {
        // Warm-up + batch-size calibration: find n such that one batch takes
        // roughly budget/10.
        let mut n: u64 = 1;
        let target = self.budget / 10;
        loop {
            let t0 = Instant::now();
            for _ in 0..n {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed();
            if dt >= target || n >= 1 << 24 {
                break;
            }
            n = (n * 2).max((n as f64 * target.as_secs_f64() / dt.as_secs_f64().max(1e-9)) as u64);
            n = n.clamp(1, 1 << 24);
        }
        // Measurement: repeat batches until the budget is spent.
        let mut samples = Vec::new();
        let mut total_iters = 0u64;
        let t_start = Instant::now();
        while t_start.elapsed() < self.budget || samples.len() < 3 {
            let t0 = Instant::now();
            for _ in 0..n {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / n as f64);
            total_iters += n;
            if samples.len() >= 200 {
                break;
            }
        }
        self.results.push(CaseResult {
            name: name.to_string(),
            iters: total_iters,
            per_iter: Summary::of(&samples),
            throughput,
        });
    }

    /// The `pulpnn-bench-v1` JSON document for this group's results.
    pub fn to_json(&self) -> Json {
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut c = BTreeMap::new();
                c.insert("name".to_string(), Json::Str(r.name.clone()));
                c.insert("iters".to_string(), Json::I64(r.iters as i64));
                c.insert("mean_s".to_string(), Json::F64(r.per_iter.mean));
                c.insert("min_s".to_string(), Json::F64(r.per_iter.min));
                c.insert("stddev_s".to_string(), Json::F64(r.per_iter.stddev));
                match &r.throughput {
                    Some((unit, amount)) => {
                        c.insert("throughput_unit".to_string(), Json::Str(unit.clone()));
                        c.insert("throughput_per_iter".to_string(), Json::F64(*amount));
                    }
                    None => {
                        c.insert("throughput_unit".to_string(), Json::Null);
                        c.insert("throughput_per_iter".to_string(), Json::Null);
                    }
                }
                Json::Obj(c)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str("pulpnn-bench-v1".to_string()));
        root.insert("group".to_string(), Json::Str(self.group.clone()));
        root.insert("budget_ms".to_string(), Json::I64(self.budget.as_millis() as i64));
        root.insert("cases".to_string(), Json::Arr(cases));
        Json::Obj(root)
    }

    /// Write `BENCH_<group>.json` into `dir` (the `pulpnn-bench-v1`
    /// schema; see docs/BENCHMARKS.md).
    pub fn write_json(&self, dir: &str) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::Path::new(dir).join(format!("BENCH_{}.json", self.group));
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    /// Render the report to stdout; also returns it for capture. When
    /// `PULPNN_BENCH_JSON` names a directory, also writes the JSON
    /// trajectory file there.
    pub fn report(&self) -> String {
        let mut out = format!("\n== bench group: {} ==\n", self.group);
        for r in &self.results {
            let mean = r.per_iter.mean;
            out.push_str(&format!(
                "{:<40} {:>12}/iter  (min {:>12}, sd {:>10}, n={})\n",
                r.name,
                fmt_time(mean),
                fmt_time(r.per_iter.min),
                fmt_time(r.per_iter.stddev),
                r.iters,
            ));
            if let Some((unit, amount)) = &r.throughput {
                out.push_str(&format!(
                    "{:<40} {:>12.3} M{}/s\n",
                    "",
                    amount / mean / 1e6,
                    unit
                ));
            }
        }
        if let Ok(dir) = std::env::var("PULPNN_BENCH_JSON") {
            match self.write_json(&dir) {
                Ok(path) => out.push_str(&format!("json: {}\n", path.display())),
                Err(e) => eprintln!("warning: could not write bench json to {dir}: {e}"),
            }
        }
        print!("{out}");
        out
    }

    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("PULPNN_BENCH_BUDGET_MS", "20");
        let mut b = Bench::new("selftest");
        b.run("add", || std::hint::black_box(1u64) + 1);
        let r = &b.results()[0];
        assert!(r.per_iter.mean > 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn json_trajectory_roundtrips() {
        std::env::set_var("PULPNN_BENCH_BUDGET_MS", "20");
        let mut b = Bench::new("jsontest");
        b.run_with_throughput("case-a", Some(("simReq".into(), 7.0)), || 1u64 + 1);
        b.run("case-b", || 2u64 + 2);
        let dir = std::env::temp_dir();
        let path = b.write_json(dir.to_str().unwrap()).expect("writable temp dir");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(text.trim()).expect("valid JSON");
        assert_eq!(doc.get("schema").as_str(), Some("pulpnn-bench-v1"));
        assert_eq!(doc.get("group").as_str(), Some("jsontest"));
        let cases = doc.get("cases").as_arr().expect("cases array");
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("name").as_str(), Some("case-a"));
        assert_eq!(cases[0].get("throughput_unit").as_str(), Some("simReq"));
        assert!(cases[0].get("mean_s").as_f64().unwrap() > 0.0);
        assert_eq!(*cases[1].get("throughput_unit"), Json::Null);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
