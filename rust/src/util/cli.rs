//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Unknown flags are collected and reported by `finish()` so every
//! subcommand validates its full argument set.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name / subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut pos = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    opts.insert(rest.to_string(), v);
                } else {
                    flags.push(rest.to_string());
                }
            } else {
                pos.push(a);
            }
        }
        Args { opts, flags, pos, consumed: Vec::new() }
    }

    /// String option with default.
    pub fn opt(&mut self, key: &str, default: &str) -> String {
        self.consumed.push(key.to_string());
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_maybe(&mut self, key: &str) -> Option<String> {
        self.consumed.push(key.to_string());
        self.opts.get(key).cloned()
    }

    /// Integer option with default; exits with a message on parse failure.
    pub fn opt_usize(&mut self, key: &str, default: usize) -> usize {
        self.consumed.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects an unsigned integer, got `{v}`");
                std::process::exit(2);
            }),
        }
    }

    pub fn opt_u64(&mut self, key: &str, default: u64) -> u64 {
        self.consumed.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects an unsigned integer, got `{v}`");
                std::process::exit(2);
            }),
        }
    }

    pub fn opt_f64(&mut self, key: &str, default: f64) -> f64 {
        self.consumed.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects a number, got `{v}`");
                std::process::exit(2);
            }),
        }
    }

    /// Boolean flag (present / absent).
    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.pos
    }

    /// Report unknown options: call after all opt()/flag() reads.
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown option(s): {}",
                unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_equals() {
        let mut a = Args::parse(argv(&["--cores", "8", "--mode=lp", "pos1"]));
        assert_eq!(a.opt_usize("cores", 1), 8);
        assert_eq!(a.opt("mode", "hp"), "lp");
        assert_eq!(a.positional(), &["pos1".to_string()]);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn flags_do_not_eat_following_flag() {
        let mut a = Args::parse(argv(&["--verbose", "--cores", "4"]));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("cores", 1), 4);
    }

    #[test]
    fn unknown_options_reported() {
        let mut a = Args::parse(argv(&["--bogus", "--cores", "2"]));
        let _ = a.opt_usize("cores", 1);
        let err = a.finish().unwrap_err();
        assert!(err.contains("--bogus"));
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(argv(&[]));
        assert_eq!(a.opt("mode", "hp"), "hp");
        assert_eq!(a.opt_f64("scale", 1.5), 1.5);
        assert!(!a.flag("verbose"));
    }
}
