//! Deterministic xorshift64* RNG.
//!
//! The whole reproduction must be deterministic across the three layers
//! (rust golden model / simulated kernels / JAX artifacts), so we use a tiny
//! seedable generator instead of an external crate. The python side mirrors
//! this exact generator in `python/compile/kernels/packing.py::Xorshift` so
//! both sides can derive identical test tensors from a shared seed.

/// xorshift64* — fast, full-period (2^64-1), statistically adequate for
/// test-vector generation (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a non-zero seed. A zero seed is mapped to a
    /// fixed odd constant because xorshift has a fixed point at 0.
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Next 32-bit value (upper half of the 64-bit output).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform value in `[0, n)`. Uses Lemire-style widening multiply;
    /// fine for test generation (modulo bias < 2^-32 for small n).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "Rng::below(0)");
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full i64 range
            return self.next_u64() as i64;
        }
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform i32 in `[lo, hi]` inclusive.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        self.range_i64(lo as i64, hi as i64) as i32
    }

    /// Uniform f64 in [0, 1).
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill a byte slice.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        for chunk in out.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Random boolean with probability `p` of `true`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_hits_bounds() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let v = r.range_i32(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = Rng::new(0);
        let v = r.next_u64();
        assert_ne!(v, 0);
    }

    #[test]
    fn unit_f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(5);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
