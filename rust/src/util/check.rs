//! Minimal property-based testing harness.
//!
//! `proptest` is not available in this offline environment, so we provide a
//! tiny deterministic property runner with case generation from [`Rng`] and
//! first-failure reporting. It intentionally has no shrinking — generators
//! are written to start from small cases (sorted size parameters) so the
//! first failing case is usually already small.

use super::rng::Rng;

/// Run `cases` random property checks. `f` receives a per-case RNG and the
/// case index and returns `Err(msg)` on failure.
///
/// Panics with a reproducible report (seed + case index) on failure.
pub fn check<F>(name: &str, cases: u32, mut f: F)
where
    F: FnMut(&mut Rng, u32) -> Result<(), String>,
{
    let base_seed = 0xC0FFEE ^ fnv1a(name.as_bytes());
    for case in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = f(&mut rng, case) {
            panic!(
                "property `{name}` failed at case {case} (base_seed={base_seed:#x}):\n  {msg}"
            );
        }
    }
}

/// FNV-1a hash, used to derive per-property seeds from the property name so
/// distinct properties explore distinct streams.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two slices are equal, reporting the first mismatch index.
pub fn expect_eq_slices<T: PartialEq + std::fmt::Debug>(
    a: &[T],
    b: &[T],
    what: &str,
) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if x != y {
            return Err(format!("{what}: first mismatch at [{i}]: {x:?} vs {y:?}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |rng, _| {
            let v = rng.below(10);
            if v < 10 { Ok(()) } else { Err(format!("{v} out of range")) }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failure() {
        check("always-fails", 5, |_, _| Err("nope".into()));
    }

    #[test]
    fn fnv1a_distinguishes_names() {
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn expect_eq_slices_reports_index() {
        let e = expect_eq_slices(&[1, 2, 3], &[1, 9, 3], "demo").unwrap_err();
        assert!(e.contains("[1]"), "{e}");
    }
}
