//! Minimal `anyhow`-style error handling for the offline build.
//!
//! The crate must build with std only (DESIGN.md §6), so instead of the
//! `anyhow` crate we provide the tiny subset the codebase needs: a
//! string-backed [`Error`], a [`Result`] alias defaulting the error type,
//! the [`crate::anyhow!`] constructor macro and a [`Context`] extension
//! trait for annotating propagated errors.

use std::fmt;

/// A boxed-string error with an optional chain of context annotations
/// (rendered outermost-first, `anyhow` style: `context: cause`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prefix the error with a context annotation.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value —
/// the shape of `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Extension trait adding `anyhow`-style context annotation to results.
pub trait Context<T> {
    /// Annotate the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Annotate the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(crate::anyhow!("base failure {}", 42))
    }

    #[test]
    fn macro_formats_and_wraps() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "base failure 42");
        let e = crate::anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn context_prefixes_outermost_first() {
        let e = fails().context("loading artifact").unwrap_err();
        assert_eq!(e.to_string(), "loading artifact: base failure 42");
        let e = fails().with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: base failure 42");
    }

    #[test]
    fn question_mark_converts_common_sources() {
        fn io_path() -> Result<Vec<u8>> {
            let bytes = std::fs::read("/definitely-not-a-real-path-xyz")?;
            Ok(bytes)
        }
        assert!(io_path().is_err());
        fn string_path() -> Result<()> {
            Err("stringy".to_string())?;
            Ok(())
        }
        assert_eq!(string_path().unwrap_err().to_string(), "stringy");
    }
}
