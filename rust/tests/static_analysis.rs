//! Tier-1 enforcement of the `pallas-lint` determinism & invariant
//! rules (D001–D011, `docs/STATIC_ANALYSIS.md`): the whole `rust/` +
//! `examples/` tree must lint clean — every diagnostic is either fixed
//! or carries a reviewed `allow(<rules>, reason = "...")` annotation
//! (suppressed diagnostics are retained with `allowed = true` and do
//! not fail the gate).
//!
//! This absorbs the old ad-hoc `rust/tests/lint.rs` doc-marker sweep:
//! its detector is now rule D005, and its shape fixtures live on below.
//! It also stress-tests the v2 structural layer: the scanner must
//! survive arbitrary token soup, and the item tree must produce sane
//! spans for every real file in the sweep.

use std::path::Path;

use pulpnn_mp::analysis::rules::is_corrupted_marker;
use pulpnn_mp::analysis::{lint_root, scanner, structure, sweep_paths};
use pulpnn_mp::util::check::check;

#[test]
fn the_tree_lints_clean_under_the_pallas_lint_rules() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_root(root).expect("the repo sweep reads every source file");
    assert!(
        report.files_scanned > 20,
        "source sweep found suspiciously few files: {}",
        report.files_scanned
    );
    let rendered: Vec<String> =
        report.diagnostics.iter().filter(|d| !d.allowed).map(|d| d.to_string()).collect();
    assert!(
        rendered.is_empty(),
        "pallas-lint diagnostics (fix the code, or annotate with \
         `// pallas-lint: allow(<rules>, reason = \"...\")` — see \
         docs/STATIC_ANALYSIS.md):\n{}",
        rendered.join("\n")
    );
}

#[test]
fn the_sweep_covers_the_linter_and_the_simulator_alike() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = sweep_paths(root).expect("sweep dirs exist");
    let has = |suffix: &str| files.iter().any(|p| p.ends_with(suffix));
    assert!(has("rust/src/analysis/rules.rs"), "the linter must lint itself");
    assert!(has("rust/src/analysis/structure.rs"), "the item-tree layer is in scope");
    assert!(has("rust/src/analysis/units.rs"), "the units layer is in scope");
    assert!(has("rust/src/coordinator/shard.rs"), "the simulator tier is in scope");
    assert!(has("rust/src/coordinator/variant.rs"), "the brownout variant table is in scope");
    assert!(has("rust/benches/brownout_scale.rs"), "self-asserting benches are in scope");
    assert!(has("examples/edge_serving.rs"), "examples are in scope");
    assert!(has("rust/tests/static_analysis.rs"), "tests are in scope");
}

/// Every real file in the sweep must round-trip through the structural
/// layer with balanced, in-bounds spans: the item tree is the base for
/// D004/D008/D009, so a file it mangles is a file the linter silently
/// mis-scopes.
#[test]
fn every_sweep_file_builds_a_well_formed_item_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = sweep_paths(root).expect("sweep dirs exist");
    let mut items_seen = 0usize;
    for path in &files {
        let text = std::fs::read_to_string(path).expect("sweep file reads");
        let line_count = text.split('\n').count() as u32;
        let scan = scanner::scan(&text);
        assert_eq!(
            scan.line_in_code.len() as u32,
            line_count,
            "{}: line_in_code tracks every physical line",
            path.display()
        );
        let tree = structure::build(&scan);
        structure::walk(&tree, &mut |it| {
            items_seen += 1;
            assert!(
                1 <= it.line && it.line <= it.end_line && it.end_line <= line_count,
                "{}: item `{}` has span {}..={} outside 1..={}",
                path.display(),
                it.name,
                it.line,
                it.end_line,
                line_count
            );
            assert!(
                it.attr_line <= it.line,
                "{}: item `{}` attributes start after its header",
                path.display(),
                it.name
            );
            if let Some((lo, hi)) = it.body {
                assert!(
                    lo <= hi && hi <= scan.tokens.len(),
                    "{}: fn `{}` body token span {lo}..{hi} out of bounds",
                    path.display(),
                    it.name
                );
            }
            if let Some((lo, hi)) = it.rhs {
                assert!(
                    lo <= hi && hi <= scan.tokens.len(),
                    "{}: let `{}` rhs token span {lo}..{hi} out of bounds",
                    path.display(),
                    it.name
                );
            }
        });
    }
    assert!(items_seen > 500, "the tree sweep should see many items, got {items_seen}");
}

/// Scanner robustness: random token soup — unterminated literals,
/// stray brace salad, half-open comments, misplaced annotations — must
/// never panic the scanner or the tree builder, and line bookkeeping
/// must stay consistent with the physical line count.
#[test]
fn random_token_soup_never_breaks_the_scanner_or_the_tree() {
    const FRAGMENTS: &[&str] = &[
        "fn", "let", "struct", "impl", "mod", "enum", "trait", "pub", "mut", "soup", "x_us",
        "y_cycles", "{", "}", "(", ")", "[", "]", "<", ">", "->", "::", "=", ";", ",", "+", "-",
        "*", "/", "0x1f", "1.5e3", "42", "\"", "\"done\"", "r#\"raw", "'", "'a", "'x'", "b\"oops",
        "//", "/*", "*/", "/* nested /* depth */", "///", "// pallas-lint: allow(D004,",
        "// pallas-lint: allow(D004, reason = \"soup\")", "#", "!", "#[cfg(test)]", "#[test]",
        "where", "unsafe", "\\",
    ];
    check("scanner token soup", 300, |rng, _case| {
        let n = 5 + rng.below(120) as usize;
        let mut text = String::new();
        for _ in 0..n {
            text.push_str(rng.pick(FRAGMENTS));
            text.push(if rng.chance(0.25) { '\n' } else { ' ' });
        }
        let scan = scanner::scan(&text);
        let line_count = text.split('\n').count();
        if scan.line_in_code.len() != line_count {
            return Err(format!(
                "line_in_code has {} entries for {} physical lines",
                scan.line_in_code.len(),
                line_count
            ));
        }
        for t in &scan.tokens {
            if t.line == 0 || t.line as usize > line_count {
                return Err(format!("token `{}` reports out-of-range line {}", t.text, t.line));
            }
        }
        let tree = structure::build(&scan);
        let mut bad = None;
        structure::walk(&tree, &mut |it| {
            if !(1 <= it.line && it.line <= it.end_line && it.end_line as usize <= line_count) {
                bad = Some(format!("item `{}` span {}..={}", it.name, it.line, it.end_line));
            }
        });
        match bad {
            Some(msg) => Err(msg),
            None => Ok(()),
        }
    });
}

// Migrated from the old rust/tests/lint.rs: the corruption shapes that
// have actually bitten (`//!` -> `/!` on a module doc, `/// [...]`-style
// lines losing slashes mid-paragraph), and the legitimate line-wrapped
// divisions that must never be flagged.
#[test]
fn the_marker_detector_catches_the_known_corruption_shapes() {
    assert!(is_corrupted_marker("/! The horizontally sharded serving tier"));
    assert!(is_corrupted_marker("    / [`merge_streams`]: crate::coordinator"));
    assert!(is_corrupted_marker("            / FIFO router queue: one front-end"));
    assert!(is_corrupted_marker("  / `Fleet` stepping API"));
    assert!(!is_corrupted_marker("//! module docs"));
    assert!(!is_corrupted_marker("/// item docs"));
    assert!(!is_corrupted_marker("// plain comment"));
    assert!(!is_corrupted_marker("    / f.devices.len() as f64"));
    assert!(!is_corrupted_marker("    / r.per_device_utilization.len().max(1) as f64"));
    assert!(!is_corrupted_marker("let x = a / b;"));
    assert!(!is_corrupted_marker(""));
}
