//! Tier-1 enforcement of the `pallas-lint` determinism & invariant
//! rules (D001–D006, `docs/STATIC_ANALYSIS.md`): the whole `rust/` +
//! `examples/` tree must lint clean — every diagnostic is either fixed
//! or carries a reviewed `allow(<rule>, reason = "...")` annotation.
//!
//! This absorbs the old ad-hoc `rust/tests/lint.rs` doc-marker sweep:
//! its detector is now rule D005, and its shape fixtures live on below.

use std::path::Path;

use pulpnn_mp::analysis::rules::is_corrupted_marker;
use pulpnn_mp::analysis::{lint_root, sweep_paths};

#[test]
fn the_tree_lints_clean_under_the_pallas_lint_rules() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_root(root).expect("the repo sweep reads every source file");
    assert!(
        report.files_scanned > 20,
        "source sweep found suspiciously few files: {}",
        report.files_scanned
    );
    let rendered: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        rendered.is_empty(),
        "pallas-lint diagnostics (fix the code, or annotate with \
         `// pallas-lint: allow(<rule>, reason = \"...\")` — see \
         docs/STATIC_ANALYSIS.md):\n{}",
        rendered.join("\n")
    );
}

#[test]
fn the_sweep_covers_the_linter_and_the_simulator_alike() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = sweep_paths(root).expect("sweep dirs exist");
    let has = |suffix: &str| files.iter().any(|p| p.ends_with(suffix));
    assert!(has("rust/src/analysis/rules.rs"), "the linter must lint itself");
    assert!(has("rust/src/coordinator/shard.rs"), "the simulator tier is in scope");
    assert!(has("rust/src/coordinator/variant.rs"), "the brownout variant table is in scope");
    assert!(has("rust/benches/brownout_scale.rs"), "self-asserting benches are in scope");
    assert!(has("examples/edge_serving.rs"), "examples are in scope");
    assert!(has("rust/tests/static_analysis.rs"), "tests are in scope");
}

// Migrated from the old rust/tests/lint.rs: the corruption shapes that
// have actually bitten (`//!` -> `/!` on a module doc, `/// [...]`-style
// lines losing slashes mid-paragraph), and the legitimate line-wrapped
// divisions that must never be flagged.
#[test]
fn the_marker_detector_catches_the_known_corruption_shapes() {
    assert!(is_corrupted_marker("/! The horizontally sharded serving tier"));
    assert!(is_corrupted_marker("    / [`merge_streams`]: crate::coordinator"));
    assert!(is_corrupted_marker("            / FIFO router queue: one front-end"));
    assert!(is_corrupted_marker("  / `Fleet` stepping API"));
    assert!(!is_corrupted_marker("//! module docs"));
    assert!(!is_corrupted_marker("/// item docs"));
    assert!(!is_corrupted_marker("// plain comment"));
    assert!(!is_corrupted_marker("    / f.devices.len() as f64"));
    assert!(!is_corrupted_marker("    / r.per_device_utilization.len().max(1) as f64"));
    assert!(!is_corrupted_marker("let x = a / b;"));
    assert!(!is_corrupted_marker(""));
}
