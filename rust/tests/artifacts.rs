//! Integration tests over the AOT artifacts: the full
//! artifact-runtime == python-golden == rust-golden == simulated-kernel chain.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! notice) when artifacts/ is absent so `cargo test` stays green on a
//! fresh checkout.

use pulpnn_mp::qnn::network::demo_cnn;
use pulpnn_mp::qnn::tensor::QTensor;
use pulpnn_mp::runtime::{verify_artifact, Manifest, Runtime};
use pulpnn_mp::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP artifact tests: {e}");
            None
        }
    }
}

#[test]
fn manifest_contains_all_27_plus_network() {
    let Some(m) = manifest() else { return };
    let refs = m.artifacts.iter().filter(|a| a.kind == "reference_layer").count();
    assert_eq!(refs, 27, "expected all 27 reference-layer artifacts");
    assert!(m.find("demo_cnn_mixed").is_some());
}

#[test]
fn reference_layer_chain_bit_exact_sample() {
    // A representative subset across all three precisions per slot
    // (the full 27 are covered by `pulpnn verify`; compiling all of them
    // in a unit test is slow).
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::cpu().expect("artifact runtime");
    for (x, w, y) in [(8, 8, 8), (4, 2, 4), (2, 4, 2), (8, 2, 8), (2, 2, 2)] {
        let Some(a) = m.find_ref_layer(x, w, y) else {
            panic!("missing ref_layer x{x}w{w}y{y}");
        };
        let report = verify_artifact(&mut rt, a).expect("verification ran");
        assert!(report.runtime_matches_golden, "{}: runtime != python golden", a.name);
        assert_eq!(report.rust_matches_golden, Some(true), "{}: rust golden", a.name);
        assert_eq!(report.kernel_matches_golden, Some(true), "{}: kernels", a.name);
    }
}

#[test]
fn demo_network_runtime_matches_rust_golden_and_simulator() {
    let Some(m) = manifest() else { return };
    let Some(a) = m.find("demo_cnn_mixed") else { return };
    let mut rt = Runtime::cpu().expect("artifact runtime");

    // 1. runtime output == python golden file
    let out = rt.execute_recorded(a).expect("execute");
    let golden_bytes = a.read_golden().unwrap();
    assert_eq!(out.to_bytes(), golden_bytes, "runtime != python golden");
    let logits = out.as_logits().expect("network emits logits").to_vec();

    // 2. rust golden model on the mirrored input == same logits
    let net = demo_cnn().materialize().unwrap();
    let mut rng = Rng::new(a.seed);
    let x = QTensor::random(&mut rng, net.spec.input, net.spec.input_bits);
    assert_eq!(x.data, a.read_input().unwrap(), "input mirror broken");
    let fwd = net.forward_golden(&x);
    assert_eq!(fwd.logits.as_ref().unwrap(), &logits, "rust golden != runtime");

    // 3. simulated GAP-8 backend == same logits
    let run = pulpnn_mp::kernels::netrun::GapBackend::default().run(&net, &x);
    assert_eq!(run.logits.as_ref().unwrap(), &logits, "simulator != runtime");
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(m) = manifest() else { return };
    let a = &m.artifacts[0];
    let mut rt = Runtime::cpu().expect("client");
    rt.load(a).unwrap();
    assert!(rt.is_loaded(&a.name));
    // pallas-lint: allow(D003, reason = "asserts the compilation cache answers in real wall-clock time")
    let t0 = std::time::Instant::now();
    rt.load(a).unwrap(); // cached: must be instant
    assert!(t0.elapsed().as_millis() < 5);
}

#[test]
fn execute_rejects_wrong_input_size() {
    let Some(m) = manifest() else { return };
    let a = &m.artifacts[0];
    let mut rt = Runtime::cpu().expect("client");
    let err = rt.execute(a, &[0u8; 3]).unwrap_err();
    assert!(err.to_string().contains("manifest says"), "{err}");
}
