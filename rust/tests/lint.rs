//! Source hygiene: corrupted doc-comment markers.
//!
//! A doc comment that loses a slash (`//!` becoming `/!`, or `/// Foo`
//! becoming `/ Foo`) is silently dropped by rustdoc — the line vanishes
//! from the rendered docs without any warning, and in expression
//! position it can even parse as a line-wrapped division. This sweep
//! fails tier-1 on the malformed shapes instead of losing documentation
//! silently; the `doc markers` CI step runs the equivalent grep so the
//! failure is also visible without a test run.

use std::path::{Path, PathBuf};

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("source directory exists") {
        let path = entry.expect("directory entry").path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// A line whose first non-whitespace token looks like a doc-comment
/// marker that lost a slash: `/!`, or a lone `/` followed by a space and
/// an uppercase letter, `[`, or a backtick. Legitimate line-wrapped
/// divisions continue with lowercase identifiers, digits or `(`, so they
/// never match.
fn is_corrupted_marker(line: &str) -> bool {
    let t = line.trim_start();
    let Some(rest) = t.strip_prefix('/') else {
        return false;
    };
    if rest.starts_with('!') {
        return true;
    }
    match rest.strip_prefix(' ') {
        Some(after) => after.starts_with(|c: char| c.is_ascii_uppercase() || c == '[' || c == '`'),
        None => false,
    }
}

#[test]
fn no_corrupted_doc_comment_markers_anywhere_in_the_crate() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut files = Vec::new();
    for dir in ["rust/src", "rust/benches", "rust/tests", "examples"] {
        rust_files(&root.join(dir), &mut files);
    }
    assert!(files.len() > 20, "source sweep found suspiciously few files: {}", files.len());
    let mut bad = Vec::new();
    for path in files {
        let text = std::fs::read_to_string(&path).expect("source file is readable UTF-8");
        for (i, line) in text.lines().enumerate() {
            if is_corrupted_marker(line) {
                bad.push(format!("{}:{}: {}", path.display(), i + 1, line.trim()));
            }
        }
    }
    assert!(
        bad.is_empty(),
        "corrupted doc-comment markers (a `/` short of a doc comment — rustdoc drops \
         these lines silently):\n{}",
        bad.join("\n")
    );
}

#[test]
fn the_marker_detector_catches_the_known_corruption_shapes() {
    // the shapes that have actually bitten: `//!` -> `/!` on a module
    // doc, `/// [...]`-style lines losing slashes mid-paragraph
    assert!(is_corrupted_marker("/! The horizontally sharded serving tier"));
    assert!(is_corrupted_marker("    / [`merge_streams`]: crate::coordinator"));
    assert!(is_corrupted_marker("            / FIFO router queue: one front-end"));
    assert!(is_corrupted_marker("  / `Fleet` stepping API"));
    // legitimate lines must never be flagged
    assert!(!is_corrupted_marker("//! module docs"));
    assert!(!is_corrupted_marker("/// item docs"));
    assert!(!is_corrupted_marker("// plain comment"));
    assert!(!is_corrupted_marker("    / f.devices.len() as f64"));
    assert!(!is_corrupted_marker("    / r.per_device_utilization.len().max(1) as f64"));
    assert!(!is_corrupted_marker("let x = a / b;"));
    assert!(!is_corrupted_marker(""));
}
