//! CLI integration tests: every evaluation subcommand must run to
//! completion and emit its expected report skeleton.

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_pulpnn"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (out, _, _) = run(&["help"]);
    for cmd in ["fig4", "table1", "fig5", "fig6", "sweep", "verify", "serve"] {
        assert!(out.contains(cmd), "help missing `{cmd}`");
    }
}

#[test]
fn unknown_command_fails_with_usage() {
    let (_, err, ok) = run(&["bogus"]);
    assert!(!ok);
    assert!(err.contains("unknown command"));
}

#[test]
fn fig4_reports_weight_rows() {
    let (out, _, ok) = run(&["fig4"]);
    assert!(ok);
    assert!(out.contains("Fig. 4"));
    for w in ["8b", "4b", "2b"] {
        assert!(out.contains(w));
    }
}

#[test]
fn table1_reports_paper_column() {
    let (out, _, ok) = run(&["table1"]);
    assert!(ok);
    assert!(out.contains("16.64")); // the paper reference column
}

#[test]
fn innerloop_cross_check_passes() {
    let (out, _, ok) = run(&["innerloop"]);
    assert!(ok, "innerloop failed: {out}");
    assert!(out.contains("14"));
    assert!(out.contains("72"));
    assert!(out.contains("140"));
    assert!(out.contains("true"), "bit-exactness column: {out}");
    assert!(!out.contains("false"));
}

#[test]
fn run_demo_network_matches_golden() {
    let (out, err, ok) = run(&["run", "--cores", "2"]);
    assert!(ok, "{err}");
    assert!(out.contains("logits match the golden model bit-exactly"), "{out}");
}

#[test]
fn footprint_reports_seven_x_band() {
    let (out, _, ok) = run(&["footprint"]);
    assert!(ok);
    assert!(out.contains("mixed (CMix-NN style)"));
}

#[test]
fn serve_simulates_fleet() {
    let (out, _, ok) = run(&["serve", "--devices", "2", "--requests", "200", "--rate", "100"]);
    assert!(ok);
    assert!(out.contains("throughput"));
    assert!(out.contains("per-device"));
}

#[test]
fn serve_simulates_sharded_multi_tenant_tier_with_cache() {
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "4",
        "--shards",
        "2",
        "--tenants",
        "2",
        "--repeat-ratio",
        "0.5",
        "--cache",
        "--policy",
        "tenancy",
        "--requests",
        "400",
        "--rate",
        "200",
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("sharded tier"), "{out}");
    assert!(out.contains("result cache"), "{out}");
    assert!(out.contains("net-switches"), "{out}");
    assert!(out.contains("queue depth"), "{out}");
    assert!(!err.contains("unknown option"), "{err}");
}

#[test]
fn serve_edf_with_stealing() {
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "2",
        "--requests",
        "300",
        "--rate",
        "500",
        "--deadline-ms",
        "20",
        "--discipline",
        "edf",
        "--steal",
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("Edf"), "{out}");
    assert!(out.contains("work steals"), "{out}");
    assert!(!err.contains("unknown option"), "{err}");
}

#[test]
fn serve_rejects_bad_discipline() {
    let (_, err, ok) = run(&["serve", "--discipline", "bogus"]);
    assert!(!ok);
    assert!(err.contains("fifo|edf"), "{err}");
}

#[test]
fn serve_closed_loop_reports_client_pool() {
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "2",
        "--closed-loop",
        "4",
        "--think-us",
        "2000",
        "--requests",
        "200",
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("closed loop: 4 client(s)"), "{out}");
    assert!(out.contains("200 requests served"), "{out}");
}

#[test]
fn serve_closed_loop_spreads_tenants_on_the_single_fleet() {
    // --tenants with --closed-loop must NOT trip the sharded-path guard:
    // the client pool spreads clients across tenant networks itself
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "2",
        "--closed-loop",
        "4",
        "--tenants",
        "2",
        "--think-us",
        "1000",
        "--requests",
        "120",
        "--policy",
        "tenancy",
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("closed loop: 4 client(s)"), "{out}");
    assert!(out.contains("120 requests served"), "{out}");
}

#[test]
fn serve_closed_loop_composes_with_the_sharded_tier() {
    // the unified tier event loop closes the feedback edge across
    // routers and shards, so --closed-loop --shards serves directly
    // (earlier revisions rejected this combination)
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "4",
        "--closed-loop",
        "4",
        "--think-us",
        "1500",
        "--shards",
        "2",
        "--cache",
        "--router-us",
        "50",
        "--requests",
        "160",
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("closed loop: 4 client(s)"), "{out}");
    assert!(out.contains("sharded tier: 2 shard(s)"), "{out}");
    assert!(out.contains("completed      : 160 of 160"), "{out}");
    assert!(!err.contains("unknown option"), "{err}");
}

#[test]
fn serve_closed_loop_sharded_trace_dump() {
    // a closed-loop sharded run records its injected arrivals, replayable
    // through --trace-in as an open-loop workload
    let path = std::env::temp_dir().join(format!("pulpnn_cl_trace_{}.jsonl", std::process::id()));
    let path_s = path.to_str().unwrap();
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "4",
        "--closed-loop",
        "3",
        "--shards",
        "2",
        "--requests",
        "90",
        "--trace-out",
        path_s,
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("dumped 90 arrivals"), "{out}");
    let (out2, err2, ok2) =
        run(&["serve", "--devices", "4", "--shards", "2", "--trace-in", path_s]);
    assert!(ok2, "{err2}");
    assert!(out2.contains("replaying trace"), "{out2}");
    assert!(out2.contains("completed      : 90 of 90"), "{out2}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_trace_roundtrip_through_files() {
    let path = std::env::temp_dir().join(format!("pulpnn_trace_{}.jsonl", std::process::id()));
    let path_s = path.to_str().unwrap();
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "2",
        "--requests",
        "200",
        "--rate",
        "300",
        "--trace-out",
        path_s,
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("dumped 200 arrivals"), "{out}");
    let text = std::fs::read_to_string(&path).expect("trace file written");
    assert_eq!(text.lines().count(), 200);
    let (out2, err2, ok2) = run(&["serve", "--devices", "2", "--trace-in", path_s]);
    assert!(ok2, "{err2}");
    assert!(out2.contains("replaying trace"), "{out2}");
    assert!(out2.contains("200 requests served"), "{out2}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_fault_injection_reports_and_replays_the_schedule() {
    // a generated fault schedule is recorded as JSONL and replayed
    // bit-exactly through --fault-trace-in
    let path = std::env::temp_dir().join(format!("pulpnn_faults_{}.jsonl", std::process::id()));
    let path_s = path.to_str().unwrap();
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "2",
        "--requests",
        "300",
        "--rate",
        "400",
        "--mtbf-us",
        "200000",
        "--mttr-us",
        "20000",
        "--retry-budget",
        "2",
        "--fault-trace-out",
        path_s,
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("fault injection: mtbf"), "{out}");
    assert!(out.contains("faults         :"), "{out}");
    assert!(out.contains("fault events to"), "{out}");
    assert!(!err.contains("unknown option"), "{err}");
    let (out2, err2, ok2) = run(&[
        "serve",
        "--devices",
        "2",
        "--requests",
        "300",
        "--rate",
        "400",
        "--fault-trace-in",
        path_s,
    ]);
    assert!(ok2, "{err2}");
    assert!(out2.contains("replaying fault trace"), "{out2}");
    assert!(out2.contains("faults         :"), "{out2}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_bounded_cache_reports_evictions() {
    let (out, err, ok) = run(&[
        "serve",
        "--devices",
        "4",
        "--shards",
        "2",
        "--tenants",
        "2",
        "--repeat-ratio",
        "0.5",
        "--cache",
        "--cache-capacity",
        "8",
        "--policy",
        "tenancy",
        "--requests",
        "400",
        "--rate",
        "200",
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("cache bounds"), "{out}");
    assert!(!err.contains("unknown option"), "{err}");
}

#[test]
fn emit_spec_roundtrips_through_loader() {
    let (out, _, ok) = run(&["emit-spec"]);
    assert!(ok);
    let spec = pulpnn_mp::util::json::Json::parse(out.trim()).expect("valid JSON");
    let net = pulpnn_mp::qnn::network::NetworkSpec::from_json(&spec).expect("parsable spec");
    assert_eq!(net.name, "demo_cnn_mixed");
    assert!(net.materialize().is_ok());
}

#[test]
fn seed_changes_workload_but_not_shape() {
    let (a, _, _) = run(&["peak", "--seed", "1"]);
    let (b, _, _) = run(&["peak", "--seed", "2"]);
    assert!(a.contains("MACs/cycle"));
    assert!(b.contains("MACs/cycle"));
}

// ---------------------------------------------------------------- lint

/// Build a throwaway lint root with one allowed D008, one active D004,
/// and a docs catalog row for every registered rule (so D010 is quiet).
fn lint_fixture_root(tag: &str) -> std::path::PathBuf {
    let root = std::env::temp_dir().join(format!("pallas_lint_cli_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let coord = root.join("rust/src/coordinator");
    std::fs::create_dir_all(&coord).unwrap();
    std::fs::create_dir_all(root.join("docs")).unwrap();
    std::fs::write(
        root.join("rust/mixed.rs"),
        "fn scaled(a_us: u64, b_ms: u64) -> u64 {\n    \
         // pallas-lint: allow(D008, reason = \"golden fixture\")\n    \
         a_us + b_ms\n}\n",
    )
    .unwrap();
    std::fs::write(coord.join("g.rs"), "fn pick(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n")
        .unwrap();
    let mut docs = String::from("| rule | summary |\n|---|---|\n");
    for r in pulpnn_mp::analysis::RULES {
        docs.push_str(&format!("| {} | {} |\n", r.id, r.summary));
    }
    std::fs::write(root.join("docs/STATIC_ANALYSIS.md"), docs).unwrap();
    root
}

#[test]
fn lint_json_output_is_golden_pinned() {
    let root = lint_fixture_root("json");
    let (out, err, ok) = run(&["lint", "--root", root.to_str().unwrap(), "--format", "json"]);
    assert!(ok, "{err}");
    let golden = concat!(
        "{\"allowed\":true,\"file\":\"rust/mixed.rs\",\"line\":3,\"message\":\"`a_us` (us) + \
         `b_ms` (ms) mixes units \u{2014} convert through a named `*_to_*` fn or fix the \
         operand\",\"rule\":\"D008\"}\n",
        "{\"allowed\":false,\"file\":\"rust/src/coordinator/g.rs\",\"line\":2,\"message\":\"\
         `.unwrap` in coordinator non-test code \u{2014} return a typed error, or annotate \
         the documented invariant with an allow(D004) reason\",\"rule\":\"D004\"}\n",
    );
    assert_eq!(out, golden, "lint --format json must match the documented JSONL schema");
    assert!(err.contains("2 files scanned, 1 diagnostics (1 allowed)"), "{err}");
    for line in out.lines() {
        let parsed = pulpnn_mp::util::json::Json::parse(line).expect("each line is valid JSON");
        assert!(parsed.get("rule").as_str().is_some());
        assert!(parsed.get("file").as_str().is_some());
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn lint_text_mode_hides_allowed_and_deny_gates_on_active() {
    let root = lint_fixture_root("deny");
    let (out, _, ok) = run(&["lint", "--root", root.to_str().unwrap()]);
    assert!(ok, "plain lint reports but does not gate");
    assert!(out.contains("D004"), "{out}");
    assert!(!out.contains("D008"), "allowed diagnostics stay out of text mode: {out}");
    let (_, _, deny_ok) = run(&["lint", "--root", root.to_str().unwrap(), "--deny"]);
    assert!(!deny_ok, "the active D004 must fail --deny");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn lint_explain_prints_the_rationale_and_rejects_unknown_rules() {
    let (out, _, ok) = run(&["lint", "--explain", "D008"]);
    assert!(ok);
    assert!(out.contains("D008"), "{out}");
    assert!(out.contains("scope:"), "{out}");
    assert!(out.len() > 120, "explain text should carry real rationale: {out}");
    let (_, err, bad_ok) = run(&["lint", "--explain", "D999"]);
    assert!(!bad_ok);
    assert!(err.contains("unknown rule"), "{err}");
}
