"""Shared numeric contract, python side (mirrors rust bit-for-bit).

This module is the python half of DESIGN.md section 4: the xorshift64*
generator, fnv1a hashing, sub-byte packing and the quantization-parameter
construction are *exact mirrors* of `rust/src/util/rng.rs`,
`rust/src/util/check.rs`, `rust/src/qnn/pack.rs` and
`rust/src/qnn/quant.rs`, so both sides materialize bit-identical weights
and test tensors from a shared seed (verified by fixtures in
`python/tests/test_mirror.py` and the rust integration tests against the
AOT'd artifacts).
"""

from __future__ import annotations

import numpy as np

_MASK64 = (1 << 64) - 1


def fnv1a(data: bytes) -> int:
    """FNV-1a over bytes, 64-bit wrap-around (mirror of util::check)."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & _MASK64
    return h


class Xorshift:
    """xorshift64* (mirror of util::rng::Rng)."""

    def __init__(self, seed: int):
        self.state = seed & _MASK64 if seed != 0 else 0x9E3779B97F4A7C15

    def next_u64(self) -> int:
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & _MASK64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & _MASK64

    def next_u32(self) -> int:
        return self.next_u64() >> 32

    def below(self, n: int) -> int:
        assert n > 0
        return (self.next_u32() * n) >> 32

    def range_i64(self, lo: int, hi: int) -> int:
        assert lo <= hi
        span = (hi - lo + 1) & _MASK64
        if span == 0:
            v = self.next_u64()
            return v - (1 << 64) if v >= (1 << 63) else v
        return lo + self.next_u64() % span

    def range_i32(self, lo: int, hi: int) -> int:
        return self.range_i64(lo, hi)


# --- sub-byte packing (little-endian within byte, C fastest) ---


def per_byte(bits: int) -> int:
    assert bits in (2, 4, 8)
    return 8 // bits


def pack_unsigned(values: np.ndarray, bits: int) -> np.ndarray:
    """Pack unsigned sub-byte values into bytes (mirror of qnn::pack)."""
    v = np.asarray(values, dtype=np.int64).ravel()
    per = per_byte(bits)
    assert v.size % per == 0, f"{v.size} values not divisible by {per}"
    assert ((v >= 0) & (v <= (1 << bits) - 1)).all(), "value out of range"
    groups = v.reshape(-1, per).astype(np.uint64)
    shifts = (np.arange(per, dtype=np.uint64) * np.uint64(bits))
    return (groups << shifts).sum(axis=1).astype(np.uint8)


def pack_signed(values: np.ndarray, bits: int) -> np.ndarray:
    v = np.asarray(values, dtype=np.int64).ravel()
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    assert ((v >= lo) & (v <= hi)).all(), "signed value out of range"
    mask = (1 << bits) - 1
    return pack_unsigned(v & mask, bits)


def unpack_unsigned(data: np.ndarray, bits: int) -> np.ndarray:
    d = np.asarray(data, dtype=np.uint8).ravel()
    per = per_byte(bits)
    mask = (1 << bits) - 1
    shifts = (np.arange(per, dtype=np.uint8) * np.uint8(bits))
    out = (d[:, None] >> shifts[None, :]) & mask
    return out.ravel().astype(np.int32)


def unpack_signed(data: np.ndarray, bits: int) -> np.ndarray:
    u = unpack_unsigned(data, bits).astype(np.int32)
    sign = 1 << (bits - 1)
    return ((u ^ sign) - sign).astype(np.int32)


# --- quantization parameters (mirror of qnn::quant) ---


class QuantParams:
    """Per-channel integer affine + shift (DESIGN.md section 4)."""

    def __init__(self, kappa, lam, shift: int, ybits: int):
        self.kappa = np.asarray(kappa, dtype=np.int64)
        self.lam = np.asarray(lam, dtype=np.int64)
        self.shift = int(shift)
        self.ybits = int(ybits)

    def quantize(self, phi: np.ndarray) -> np.ndarray:
        """phi: [..., channels] int array -> quantized outputs."""
        p = np.asarray(phi, dtype=np.int64)
        v = (p * self.kappa + self.lam) >> self.shift
        return np.clip(v, 0, (1 << self.ybits) - 1).astype(np.int32)

    def thresholds(self) -> np.ndarray:
        """[channels, 2^ybits - 1], t_k = ceil((k<<shift - lambda)/kappa)."""
        levels = (1 << self.ybits) - 1
        k = np.arange(1, levels + 1, dtype=np.int64)[None, :]
        num = (k << self.shift) - self.lam[:, None]
        den = self.kappa[:, None]
        t = -((-num) // den)  # ceil division, kappa > 0
        return np.clip(t, -(2**31), 2**31 - 1).astype(np.int64)


def random_params(
    rng: Xorshift, channels: int, ybits: int, phi_max: int, k: int
) -> QuantParams:
    """Exact mirror of qnn::quant::random_params (same draw order): the
    affine map targets the *typical* accumulator range phi_max/isqrt(k)
    so deep networks do not saturate (see the rust doc comment)."""
    import math

    umax = (1 << ybits) - 1
    phi_typ = max(phi_max // max(math.isqrt(k), 1), 1)
    shift = 0
    while (phi_typ >> shift) > umax and shift < 24:
        shift += 1
    kappa_hi = min(max((umax << shift) // phi_typ, 1) * 2, 127)
    kappa = [rng.range_i32(1, kappa_hi) for _ in range(channels)]
    center = (umax // 2) << shift
    jitter = max((umax << shift) // 4, 1)
    lam = [center + rng.range_i64(-jitter, jitter) for _ in range(channels)]
    return QuantParams(kappa, lam, shift, ybits)


def random_unsigned(rng: Xorshift, n: int, bits: int) -> np.ndarray:
    """Mirror of QTensor::random's draw order (range_i32(0, umax))."""
    umax = (1 << bits) - 1
    return np.array([rng.range_i32(0, umax) for _ in range(n)], dtype=np.int32)


def random_signed(rng: Xorshift, n: int, bits: int) -> np.ndarray:
    """Mirror of QWeights::random: symmetric zero-mean [-smax, smax]."""
    hi = (1 << (bits - 1)) - 1
    return np.array([rng.range_i32(-hi, hi) for _ in range(n)], dtype=np.int32)
