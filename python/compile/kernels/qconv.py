"""Layer 1: the mixed-precision quantized matmul-conv as a Pallas kernel.

Hardware adaptation (DESIGN.md section 3): the paper's GAP-8 inner loop
(`pv.sdotusp.b` 4-way MACs fed by `p.bext` unpacking of packed sub-byte
words) is re-thought for a TPU-shaped target:

* HBM traffic stays at the *packed* footprint — the kernel receives packed
  uint8 blocks for both the im2col'd activations and the weights; the
  BlockSpec grid streams one (pixel-tile, channel-tile) pair per step into
  VMEM.
* Unpacking is a vectorized shift/mask epilogue on the VMEM tile (the
  `p.bext` analogue at tile granularity).
* The 4x2 register tile becomes one int32 MXU matmul over the whole
  (pixel-tile x K) x (K x channel-tile) block.
* The threshold re-quantization of the sub-byte QntPack is a branch-free
  `sum(phi >= t_k)` comparison reduction fused into the tile epilogue;
  8-bit outputs use the affine (kappa*phi + lambda) >> shift path.
* Outputs are re-packed to uint8 before leaving VMEM.

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom calls; the interpret-mode lowering produces plain HLO that the rust
runtime loads and runs (numerics are identical; TPU performance is
estimated structurally in DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_unsigned(packed, bits: int):
    """[..., B] uint8 -> [..., B * 8/bits] int32, zero-extended."""
    if bits == 8:
        return packed.astype(jnp.int32)
    per = 8 // bits
    mask = (1 << bits) - 1
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    u = (packed.astype(jnp.int32)[..., None] >> shifts) & mask
    return u.reshape(*packed.shape[:-1], packed.shape[-1] * per)


def _unpack_signed(packed, bits: int):
    """[..., B] uint8 -> [..., B * 8/bits] int32, sign-extended."""
    u = _unpack_unsigned(packed, bits)
    if bits == 8:
        return ((u ^ 0x80) - 0x80).astype(jnp.int32)
    sign = 1 << (bits - 1)
    return ((u ^ sign) - sign).astype(jnp.int32)


def _pack_unsigned(vals, bits: int):
    """[..., N] int32 in [0, 2^bits) -> [..., N * bits/8] uint8."""
    if bits == 8:
        return vals.astype(jnp.uint8)
    per = 8 // bits
    v = vals.reshape(*vals.shape[:-1], vals.shape[-1] // per, per)
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    return (v << shifts).sum(axis=-1).astype(jnp.uint8)


def _qconv_kernel(xp_ref, wp_ref, thr_ref, kl_ref, yp_ref, *, xbits, wbits, ybits):
    """One grid step: [TP, K/perx] x [TC, K/perw] -> packed [TP, TC/pery].

    thr_ref: [TC, 2^ybits - 1] int32 thresholds (sub-byte outputs).
    kl_ref:  [TC, 2] int32 (kappa, lambda) plus the shift folded into
             thr/kl by the caller for the 8-bit path; see qconv_call.
    """
    x = _unpack_unsigned(xp_ref[...], xbits)  # [TP, K]
    w = _unpack_signed(wp_ref[...], wbits)  # [TC, K]
    # the MXU step: one int32 matmul per tile
    acc = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # [TP, TC]
    if ybits == 8:
        kappa = kl_ref[:, 0][None, :]  # [1, TC]
        lam = kl_ref[:, 1][None, :]
        shift = kl_ref[0, 2]
        y = jnp.right_shift(acc * kappa + lam, shift)
        y = jnp.clip(y, 0, 255)
    else:
        # branch-free threshold ladder: count thresholds <= phi
        t = thr_ref[...]  # [TC, L]
        y = (acc[:, :, None] >= t[None, :, :]).sum(axis=-1).astype(jnp.int32)
    yp_ref[...] = _pack_unsigned(y, ybits)


def qconv_call(x_im2col_packed, w_packed, thr, kl, *, xbits, wbits, ybits, tile_p, tile_c):
    """Invoke the Pallas kernel over a (P/tile_p, Cout/tile_c) grid.

    x_im2col_packed: [P, K/perx] uint8
    w_packed:        [Cout, K/perw] uint8
    thr:             [Cout, 2^ybits - 1] int32 (dummy [Cout, 1] for y8)
    kl:              [Cout, 3] int32 (kappa, lambda, shift) (y8 path)
    returns          [P, Cout/pery] uint8
    """
    p, _ = x_im2col_packed.shape
    cout = w_packed.shape[0]
    assert p % tile_p == 0, f"P={p} not divisible by tile_p={tile_p}"
    assert cout % tile_c == 0
    pery = 8 // ybits
    grid = (p // tile_p, cout // tile_c)
    return pl.pallas_call(
        functools.partial(_qconv_kernel, xbits=xbits, wbits=wbits, ybits=ybits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_p, x_im2col_packed.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_c, w_packed.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_c, thr.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_c, kl.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_p, tile_c // pery), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, cout // pery), jnp.uint8),
        interpret=True,
    )(x_im2col_packed, w_packed, thr, kl)


def im2col_packed(x_packed_hwc, h, w, c, kh, kw, stride, pad, xbits):
    """Packed-byte im2col in plain JAX (Layer 2 keeps the channel dim
    packed; the window gather happens at byte granularity so HBM-side
    tensors never hold unpacked data).

    x_packed_hwc: [H, W, C/per] uint8 -> [P, KH*KW*C/per] uint8
    """
    per = 8 // xbits
    cb = c // per
    x = x_packed_hwc.reshape(h, w, cb)
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    out_h = (h + 2 * pad - kh) // stride + 1
    out_w = (w + 2 * pad - kw) // stride + 1
    # gather rows: indices are static -> lowered to slices/concats
    rows = []
    for oh in range(out_h):
        row = []
        for ow in range(out_w):
            win = jax.lax.dynamic_slice(
                xp, (oh * stride, ow * stride, 0), (kh, kw, cb)
            )
            row.append(win.reshape(-1))
        rows.append(jnp.stack(row))
    return jnp.concatenate(rows, axis=0)


def pick_tile(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (VMEM-sized tiles)."""
    t = min(preferred, n)
    while n % t != 0:
        t -= 1
    return t


def qconv_layer(x_packed_hwc, w_packed, thr, kl, spec):
    """Full conv layer on packed tensors (the L2 building block).

    spec: kernels.ref.ConvSpec. Returns [out_h, out_w, Cout/pery] uint8.
    """
    cols = im2col_packed(
        x_packed_hwc,
        spec.h,
        spec.w,
        spec.c,
        spec.kh,
        spec.kw,
        spec.stride,
        spec.pad,
        spec.xbits,
    )
    tile_p = pick_tile(spec.out_h * spec.out_w, 32)
    tile_c = pick_tile(spec.cout, 32)
    y = qconv_call(
        cols,
        w_packed,
        thr,
        kl,
        xbits=spec.xbits,
        wbits=spec.wbits,
        ybits=spec.ybits,
        tile_p=tile_p,
        tile_c=tile_c,
    )
    pery = 8 // spec.ybits
    return y.reshape(spec.out_h, spec.out_w, spec.cout // pery)


def quant_operands(q, ybits: int):
    """Build the (thr, kl) kernel operands from QuantParams."""
    import numpy as np

    if ybits == 8:
        thr = np.zeros(((q.kappa.shape[0]), 1), dtype=np.int32)
        kl = np.stack(
            [
                q.kappa.astype(np.int32),
                q.lam.astype(np.int32),
                np.full_like(q.kappa, q.shift).astype(np.int32),
            ],
            axis=1,
        )
    else:
        thr = q.thresholds().astype(np.int32)
        kl = np.zeros((q.kappa.shape[0], 3), dtype=np.int32)
    return thr, kl
