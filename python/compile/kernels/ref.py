"""Pure-numpy oracle for the mixed-precision quantized convolution.

This is the python golden model the Pallas kernel (`qconv.py`) is tested
against, with semantics identical to `rust/src/qnn/golden.rs`: HWC ifmaps
(unsigned), OHWI weights (signed two's complement), i32 accumulation, the
affine-shift `quant` of Eq. 3 (floor shift, clamp to the unsigned output
range), little-endian sub-byte packing.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import packing
from .packing import QuantParams


@dataclass(frozen=True)
class ConvSpec:
    """Convolution layer geometry + precisions (mirror of qnn::ConvSpec)."""

    h: int
    w: int
    c: int
    cout: int
    kh: int
    kw: int
    stride: int
    pad: int
    xbits: int
    wbits: int
    ybits: int

    @property
    def out_h(self) -> int:
        return (self.h + 2 * self.pad - self.kh) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.w + 2 * self.pad - self.kw) // self.stride + 1

    @property
    def im2col_len(self) -> int:
        return self.kh * self.kw * self.c

    @property
    def phi_max_abs(self) -> int:
        return self.im2col_len * ((1 << self.xbits) - 1) * (1 << (self.wbits - 1))

    def macs(self) -> int:
        return self.out_h * self.out_w * self.cout * self.im2col_len


def reference_layer(xbits: int, wbits: int, ybits: int) -> ConvSpec:
    """The paper's Reference Layer: 32x16x16 in, 64x16x16 out, 3x3."""
    return ConvSpec(16, 16, 32, 64, 3, 3, 1, 1, xbits, wbits, ybits)


def im2col(spec: ConvSpec, x_vals: np.ndarray) -> np.ndarray:
    """[H,W,C] values -> [P, K] im2col matrix with zero padding."""
    x = x_vals.reshape(spec.h, spec.w, spec.c)
    xp = np.pad(x, ((spec.pad, spec.pad), (spec.pad, spec.pad), (0, 0)))
    rows = []
    for oh in range(spec.out_h):
        for ow in range(spec.out_w):
            win = xp[
                oh * spec.stride : oh * spec.stride + spec.kh,
                ow * spec.stride : ow * spec.stride + spec.kw,
                :,
            ]
            rows.append(win.ravel())
    return np.stack(rows).astype(np.int32)


def conv2d_acc(spec: ConvSpec, x_packed: np.ndarray, w_packed: np.ndarray) -> np.ndarray:
    """Packed inputs -> raw i32 accumulators [P, Cout]."""
    xv = packing.unpack_unsigned(x_packed, spec.xbits)[: spec.h * spec.w * spec.c]
    wv = packing.unpack_signed(w_packed, spec.wbits)[: spec.cout * spec.im2col_len]
    cols = im2col(spec, xv)  # [P, K]
    wmat = wv.reshape(spec.cout, spec.im2col_len)  # [Cout, K]
    acc = cols.astype(np.int64) @ wmat.T.astype(np.int64)
    assert (np.abs(acc) < 2**31).all(), "accumulator overflow"
    return acc.astype(np.int32)


def conv2d(
    spec: ConvSpec, x_packed: np.ndarray, w_packed: np.ndarray, q: QuantParams
) -> np.ndarray:
    """Full layer: returns the packed ofmap bytes ([H*W*Cout/per] u8)."""
    acc = conv2d_acc(spec, x_packed, w_packed)
    y = q.quantize(acc)  # [P, Cout]
    return packing.pack_unsigned(y.ravel(), spec.ybits)


def quantize_thresholds(q: QuantParams, acc: np.ndarray) -> np.ndarray:
    """Threshold formulation: #{k : phi >= t_k} — must equal q.quantize."""
    t = q.thresholds()  # [C, L]
    phi = np.asarray(acc, dtype=np.int64)  # [..., C]
    return (phi[..., None] >= t).sum(axis=-1).astype(np.int32)


def make_test_case(seed: int, spec: ConvSpec):
    """Deterministic (x_packed, w_packed, quant) for a spec — the same
    draw order as the rust tests use for cross-validation fixtures."""
    rng = packing.Xorshift(seed)
    n_x = spec.h * spec.w * spec.c
    x = packing.pack_unsigned(packing.random_unsigned(rng, n_x, spec.xbits), spec.xbits)
    n_w = spec.cout * spec.im2col_len
    w = packing.pack_signed(packing.random_signed(rng, n_w, spec.wbits), spec.wbits)
    q = packing.random_params(rng, spec.cout, spec.ybits, spec.phi_max_abs, spec.im2col_len)
    return x, w, q
