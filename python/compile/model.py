"""Layer 2: the QNN compute graph in JAX, built from the shared network
spec JSON (the same format `rust/src/qnn/network.rs` parses) and the L1
Pallas kernels.

Weights and quantization parameters are materialized with the mirrored
xorshift generator (`kernels.packing`) using the exact per-layer draw
order of `NetworkSpec::materialize`, so the AOT'd artifact computes with
bit-identical parameters to the rust golden model — verified end-to-end by
`rust/tests/artifacts.rs`.

Build-time only: nothing here runs on the request path.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .kernels import packing, qconv, ref
from .kernels.packing import Xorshift, fnv1a


@dataclass
class ConvLayer:
    spec: ref.ConvSpec
    name: str
    w_packed: np.ndarray  # [Cout, K/perw] uint8
    thr: np.ndarray
    kl: np.ndarray
    quant: packing.QuantParams


@dataclass
class PoolLayer:
    name: str
    kind: str  # "max" | "avg"
    h: int
    w: int
    c: int
    window: int
    stride: int
    bits: int

    @property
    def out_h(self):
        return (self.h - self.window) // self.stride + 1

    @property
    def out_w(self):
        return (self.w - self.window) // self.stride + 1


@dataclass
class GlobalAvgLayer:
    name: str
    h: int
    w: int
    c: int
    bits: int


@dataclass
class DenseHeadLayer:
    name: str
    in_features: int
    classes: int
    xbits: int
    wbits: int
    weights: np.ndarray  # [classes, in_features] int32


@dataclass
class Model:
    name: str
    input_h: int
    input_w: int
    input_c: int
    input_bits: int
    seed: int
    layers: list = field(default_factory=list)


def demo_cnn_spec() -> dict:
    """The built-in demo network (mirror of qnn::network::demo_cnn)."""
    return {
        "name": "demo_cnn_mixed",
        "input": {"h": 32, "w": 32, "c": 4, "bits": 8},
        "seed": 2020,
        "layers": [
            {"kind": "conv", "name": "conv0", "cout": 16, "kh": 3, "kw": 3,
             "stride": 1, "pad": 1, "xbits": 8, "wbits": 8, "ybits": 4},
            {"kind": "maxpool", "name": "pool0", "window": 2, "stride": 2},
            {"kind": "conv", "name": "conv1", "cout": 32, "kh": 3, "kw": 3,
             "stride": 1, "pad": 1, "xbits": 4, "wbits": 4, "ybits": 4},
            {"kind": "maxpool", "name": "pool1", "window": 2, "stride": 2},
            {"kind": "conv", "name": "conv2", "cout": 32, "kh": 3, "kw": 3,
             "stride": 1, "pad": 1, "xbits": 4, "wbits": 2, "ybits": 2},
            {"kind": "conv", "name": "conv3", "cout": 64, "kh": 3, "kw": 3,
             "stride": 1, "pad": 1, "xbits": 2, "wbits": 4, "ybits": 8},
            {"kind": "global_avgpool", "name": "gap"},
            {"kind": "dense_head", "name": "head", "classes": 10, "wbits": 8},
        ],
    }


def materialize(spec: dict) -> Model:
    """Build a Model with deterministic weights (mirror of
    NetworkSpec::materialize: per-layer seed = spec.seed ^ fnv1a(name);
    conv draws all OHWI weights, then quant params)."""
    inp = spec["input"]
    model = Model(
        name=spec["name"],
        input_h=inp["h"],
        input_w=inp["w"],
        input_c=inp["c"],
        input_bits=inp["bits"],
        seed=spec["seed"],
    )
    h, w, c, bits = inp["h"], inp["w"], inp["c"], inp["bits"]
    for i, ldef in enumerate(spec["layers"]):
        name = ldef.get("name", f"layer{i}")
        seed = spec["seed"] ^ fnv1a(name.encode())
        kind = ldef["kind"]
        if kind == "conv":
            cspec = ref.ConvSpec(
                h, w, c,
                ldef["cout"], ldef["kh"], ldef["kw"],
                ldef.get("stride", 1), ldef.get("pad", 0),
                ldef["xbits"], ldef["wbits"], ldef["ybits"],
            )
            assert cspec.xbits == bits, f"{name}: xbits {cspec.xbits} != incoming {bits}"
            rng = Xorshift(seed)
            n_w = cspec.cout * cspec.im2col_len
            wv = packing.random_signed(rng, n_w, cspec.wbits)
            q = packing.random_params(rng, cspec.cout, cspec.ybits, cspec.phi_max_abs, cspec.im2col_len)
            w_packed = packing.pack_signed(wv, cspec.wbits).reshape(cspec.cout, -1)
            thr, kl = qconv.quant_operands(q, cspec.ybits)
            model.layers.append(ConvLayer(cspec, name, w_packed, thr, kl, q))
            h, w, c, bits = cspec.out_h, cspec.out_w, cspec.cout, cspec.ybits
        elif kind in ("maxpool", "avgpool"):
            lay = PoolLayer(
                name, "max" if kind == "maxpool" else "avg",
                h, w, c, ldef["window"], ldef.get("stride", ldef["window"]), bits,
            )
            model.layers.append(lay)
            h, w = lay.out_h, lay.out_w
        elif kind == "global_avgpool":
            assert (h * w) & (h * w - 1) == 0, "global_avgpool needs pow2 H*W"
            model.layers.append(GlobalAvgLayer(name, h, w, c, bits))
            h, w = 1, 1
        elif kind == "dense_head":
            rng = Xorshift(seed)
            n = h * w * c * ldef["classes"]
            wv = packing.random_signed(rng, n, ldef["wbits"])
            model.layers.append(
                DenseHeadLayer(
                    name, h * w * c, ldef["classes"], bits, ldef["wbits"],
                    wv.reshape(ldef["classes"], h * w * c),
                )
            )
            h, w, c = 1, 1, ldef["classes"]
        else:
            raise ValueError(f"unknown layer kind {kind}")
    return model


# --- jax forward over packed tensors ---


def _unpack_hwc(x_packed, bits):
    """[H, W, C/per] uint8 -> [H, W, C] int32."""
    return qconv._unpack_unsigned(x_packed, bits)


def _repack_hwc(vals, bits):
    return qconv._pack_unsigned(vals, bits)


def forward(model: Model, x_packed_hwc):
    """The jittable forward pass: packed uint8 input -> output.

    Returns logits [classes] int32 if the model ends in a head, else the
    final packed activation.
    """
    cur = x_packed_hwc
    for lay in model.layers:
        if isinstance(lay, ConvLayer):
            cur = qconv.qconv_layer(
                cur,
                jnp.asarray(lay.w_packed),
                jnp.asarray(lay.thr),
                jnp.asarray(lay.kl),
                lay.spec,
            )
        elif isinstance(lay, PoolLayer):
            v = _unpack_hwc(cur, lay.bits)  # [H, W, C]
            oh, ow = lay.out_h, lay.out_w
            init = None
            for kh in range(lay.window):
                for kw in range(lay.window):
                    win = v[kh : kh + oh * lay.stride : lay.stride,
                            kw : kw + ow * lay.stride : lay.stride, :]
                    if init is None:
                        init = win
                    elif lay.kind == "max":
                        init = jnp.maximum(init, win)
                    else:
                        init = init + win
            if lay.kind == "avg":
                shift = (lay.window * lay.window).bit_length() - 1
                init = jnp.right_shift(init, shift)
            cur = _repack_hwc(init, lay.bits)
        elif isinstance(lay, GlobalAvgLayer):
            v = _unpack_hwc(cur, lay.bits)
            s = v.reshape(-1, lay.c).sum(axis=0)
            n = lay.h * lay.w
            shift = n.bit_length() - 1
            avg = jnp.right_shift(s + (1 << (shift - 1)), shift)
            cur = _repack_hwc(avg[None, None, :], lay.bits)
        elif isinstance(lay, DenseHeadLayer):
            v = _unpack_hwc(cur, lay.xbits).reshape(-1)  # [in_features]
            wmat = jnp.asarray(lay.weights, dtype=jnp.int32)
            cur = wmat @ v  # [classes] int32 logits
        else:
            raise TypeError(type(lay))
    return cur


# --- numpy oracle of the same network (mirror of Network::forward_golden) ---


def forward_numpy(model: Model, x_packed_hwc: np.ndarray):
    """Independent numpy forward for golden files (no jax involved)."""
    cur = np.asarray(x_packed_hwc, dtype=np.uint8).ravel()
    h, w, c, bits = model.input_h, model.input_w, model.input_c, model.input_bits
    for lay in model.layers:
        if isinstance(lay, ConvLayer):
            cur = ref.conv2d(lay.spec, cur, lay.w_packed.ravel(), lay.quant)
            h, w, c, bits = lay.spec.out_h, lay.spec.out_w, lay.spec.cout, lay.spec.ybits
        elif isinstance(lay, PoolLayer):
            v = packing.unpack_unsigned(cur, bits)[: h * w * c].reshape(h, w, c)
            oh, ow = lay.out_h, lay.out_w
            init = None
            for kh in range(lay.window):
                for kw in range(lay.window):
                    win = v[kh : kh + oh * lay.stride : lay.stride,
                            kw : kw + ow * lay.stride : lay.stride, :]
                    if init is None:
                        init = win.copy()
                    elif lay.kind == "max":
                        init = np.maximum(init, win)
                    else:
                        init = init + win
            if lay.kind == "avg":
                init = init >> ((lay.window * lay.window).bit_length() - 1)
            cur = packing.pack_unsigned(init.ravel(), bits)
            h, w = oh, ow
        elif isinstance(lay, GlobalAvgLayer):
            v = packing.unpack_unsigned(cur, bits)[: h * w * c].reshape(-1, c)
            s = v.sum(axis=0)
            shift = (h * w).bit_length() - 1
            avg = (s + (1 << (shift - 1))) >> shift
            cur = packing.pack_unsigned(avg, bits)
            h, w = 1, 1
        elif isinstance(lay, DenseHeadLayer):
            v = packing.unpack_unsigned(cur, lay.xbits)[: lay.in_features]
            cur = (lay.weights.astype(np.int64) @ v.astype(np.int64)).astype(np.int32)
        else:
            raise TypeError(type(lay))
    return cur


def random_input(model: Model, seed: int) -> np.ndarray:
    """Deterministic packed input [H, W, C/per] uint8 (QTensor::random
    draw order with Xorshift(seed))."""
    rng = Xorshift(seed)
    n = model.input_h * model.input_w * model.input_c
    vals = packing.random_unsigned(rng, n, model.input_bits)
    per = packing.per_byte(model.input_bits)
    return packing.pack_unsigned(vals, model.input_bits).reshape(
        model.input_h, model.input_w, model.input_c // per
    )


def load_spec_file(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
