"""AOT pipeline: lower the L2 JAX graphs to HLO *text* artifacts the rust
runtime loads via the PJRT C API.

HLO text (not serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs, per artifact:
  artifacts/<name>.hlo.txt     the lowered module (return_tuple=True)
  artifacts/<name>.input.bin   packed uint8 input bytes
  artifacts/<name>.golden.bin  expected output (packed u8 / i32 LE logits)
  artifacts/manifest.json      index with shapes, dtypes, precisions

Run once by `make artifacts`; python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import packing, qconv, ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big weight
    # constants as `constant({...})`, which the 0.5.1 text parser reads
    # back as ZEROS — the artifact would silently compute with zero weights.
    return comp.as_hlo_text(True)


def export_reference_layer(out_dir: str, xbits: int, wbits: int, ybits: int, seed: int):
    """One of the 27 Reference Layer kernels as a standalone artifact."""
    spec = ref.reference_layer(xbits, wbits, ybits)
    x_packed, w_packed, q = ref.make_test_case(seed, spec)
    golden = ref.conv2d(spec, x_packed, w_packed, q)
    thr, kl = qconv.quant_operands(q, ybits)

    perx = packing.per_byte(xbits)
    x_hwc = x_packed.reshape(spec.h, spec.w, spec.c // perx)
    w2d = w_packed.reshape(spec.cout, -1)

    def fn(x):
        return (
            qconv.qconv_layer(
                x, jnp.asarray(w2d), jnp.asarray(thr), jnp.asarray(kl), spec
            ),
        )

    name = f"ref_layer_x{xbits}w{wbits}y{ybits}"
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct(x_hwc.shape, jnp.uint8)
    )
    _write(out_dir, name, to_hlo_text(lowered), x_hwc.tobytes(), golden.tobytes())
    pery = packing.per_byte(ybits)
    return {
        "name": name,
        "kind": "reference_layer",
        "xbits": xbits,
        "wbits": wbits,
        "ybits": ybits,
        "seed": seed,
        "input_shape": list(x_hwc.shape),
        "input_dtype": "u8",
        "output_shape": [spec.out_h, spec.out_w, spec.cout // pery],
        "output_dtype": "u8",
        "macs": spec.macs(),
    }


def export_network(out_dir: str, spec_dict: dict, seed: int):
    """A full network (demo CNN or a user spec file) as one artifact."""
    m = model_mod.materialize(spec_dict)
    x = model_mod.random_input(m, seed)
    golden = model_mod.forward_numpy(m, x)

    def fn(xin):
        return (model_mod.forward(m, xin),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct(x.shape, jnp.uint8))
    name = m.name
    golden_bytes = (
        golden.astype("<i4").tobytes()
        if golden.dtype != np.uint8
        else golden.tobytes()
    )
    _write(out_dir, name, to_hlo_text(lowered), x.tobytes(), golden_bytes)
    head = [l for l in m.layers if isinstance(l, model_mod.DenseHeadLayer)]
    return {
        "name": name,
        "kind": "network",
        "seed": seed,
        "input_shape": list(x.shape),
        "input_dtype": "u8",
        "output_shape": [head[0].classes] if head else [],
        "output_dtype": "i32" if head else "u8",
        "spec": spec_dict,
    }


def _write(out_dir: str, name: str, hlo: str, input_bytes: bytes, golden: bytes):
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)
    with open(os.path.join(out_dir, f"{name}.input.bin"), "wb") as f:
        f.write(input_bytes)
    with open(os.path.join(out_dir, f"{name}.golden.bin"), "wb") as f:
        f.write(golden)
    print(f"  wrote {name}: hlo {len(hlo) // 1024} KiB, "
          f"input {len(input_bytes)} B, golden {len(golden)} B")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=2020)
    ap.add_argument(
        "--ref-combos",
        default="all",
        help="'all' (27 permutations) or comma list like 8-8-8,4-2-4",
    )
    ap.add_argument("--network-spec", default=None,
                    help="optional network spec JSON file (default: demo CNN)")
    ap.add_argument("--skip-network", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"seed": args.seed, "artifacts": []}

    if args.ref_combos == "all":
        combos = [(x, w, y) for w in (8, 4, 2) for x in (8, 4, 2) for y in (8, 4, 2)]
    else:
        combos = [tuple(int(v) for v in c.split("-")) for c in args.ref_combos.split(",")]
    print(f"exporting {len(combos)} reference-layer artifacts...")
    for x, w, y in combos:
        manifest["artifacts"].append(
            export_reference_layer(args.out_dir, x, w, y, args.seed)
        )

    if not args.skip_network:
        spec = (
            model_mod.load_spec_file(args.network_spec)
            if args.network_spec
            else model_mod.demo_cnn_spec()
        )
        print(f"exporting network `{spec['name']}`...")
        manifest["artifacts"].append(export_network(args.out_dir, spec, args.seed))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
