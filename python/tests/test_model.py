"""L2 model tests: the jax forward against the numpy oracle, shapes,
determinism, and spec parsing."""

import jax
import numpy as np
import pytest

from compile import model as model_mod
from compile.kernels import packing


@pytest.fixture(scope="module")
def demo():
    return model_mod.materialize(model_mod.demo_cnn_spec())


def test_demo_materializes(demo):
    assert len(demo.layers) == 8
    conv0 = demo.layers[0]
    assert isinstance(conv0, model_mod.ConvLayer)
    assert conv0.spec.cout == 16
    head = demo.layers[-1]
    assert isinstance(head, model_mod.DenseHeadLayer)
    assert head.classes == 10


def test_materialize_deterministic():
    m1 = model_mod.materialize(model_mod.demo_cnn_spec())
    m2 = model_mod.materialize(model_mod.demo_cnn_spec())
    np.testing.assert_array_equal(m1.layers[0].w_packed, m2.layers[0].w_packed)
    np.testing.assert_array_equal(m1.layers[-1].weights, m2.layers[-1].weights)


def test_jax_forward_matches_numpy_oracle(demo):
    x = model_mod.random_input(demo, 2020)
    want = model_mod.forward_numpy(demo, x)
    got = np.asarray(jax.jit(lambda xin: model_mod.forward(demo, xin))(x))
    np.testing.assert_array_equal(got, want)


def test_logits_shape_and_dtype(demo):
    x = model_mod.random_input(demo, 7)
    logits = model_mod.forward_numpy(demo, x)
    assert logits.shape == (10,)
    assert logits.dtype == np.int32


def test_different_inputs_different_logits(demo):
    a = model_mod.forward_numpy(demo, model_mod.random_input(demo, 1))
    b = model_mod.forward_numpy(demo, model_mod.random_input(demo, 2))
    assert not np.array_equal(a, b)


def test_precision_chain_enforced():
    spec = model_mod.demo_cnn_spec()
    spec["layers"][2]["xbits"] = 8  # conv1 expects conv0's 4-bit output
    with pytest.raises(AssertionError):
        model_mod.materialize(spec)


def test_small_custom_network_forward():
    spec = {
        "name": "tiny",
        "input": {"h": 8, "w": 8, "c": 4, "bits": 8},
        "seed": 5,
        "layers": [
            {"kind": "conv", "name": "c0", "cout": 8, "kh": 3, "kw": 3,
             "stride": 1, "pad": 1, "xbits": 8, "wbits": 4, "ybits": 4},
            {"kind": "avgpool", "name": "p0", "window": 2, "stride": 2},
            {"kind": "global_avgpool", "name": "gap"},
            {"kind": "dense_head", "name": "head", "classes": 4, "wbits": 8},
        ],
    }
    m = model_mod.materialize(spec)
    x = model_mod.random_input(m, 1)
    want = model_mod.forward_numpy(m, x)
    got = np.asarray(model_mod.forward(m, x))
    np.testing.assert_array_equal(got, want)


def test_weight_draws_mirror_contract():
    """The first weights of conv0 must come from Xorshift(seed ^ fnv1a(name))."""
    demo = model_mod.materialize(model_mod.demo_cnn_spec())
    rng = packing.Xorshift(2020 ^ packing.fnv1a(b"conv0"))
    expect = packing.random_signed(rng, 16 * 9 * 4, 8)
    got = packing.unpack_signed(demo.layers[0].w_packed.ravel(), 8)
    np.testing.assert_array_equal(got[: expect.size], expect)
