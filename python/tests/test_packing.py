"""Packing / quantization contract tests (hypothesis property sweeps)."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import packing

BITS = st.sampled_from([2, 4, 8])


def test_pack_examples():
    assert packing.pack_unsigned(np.array([1, 2]), 4).tolist() == [0x21]
    assert packing.pack_unsigned(np.array([3, 0, 1, 2]), 2).tolist() == [0b10010011]
    assert packing.pack_signed(np.array([-1, -8]), 4).tolist() == [0x8F]
    assert packing.unpack_signed(np.array([0x8F], dtype=np.uint8), 4).tolist() == [-1, -8]


@settings(max_examples=100, deadline=None)
@given(BITS, st.integers(1, 64), st.integers(0, 2**32 - 1))
def test_roundtrip_unsigned(bits, groups, seed):
    rng = np.random.default_rng(seed)
    n = groups * packing.per_byte(bits)
    vals = rng.integers(0, 1 << bits, n)
    packed = packing.pack_unsigned(vals, bits)
    assert packed.size == n // packing.per_byte(bits)
    assert (packing.unpack_unsigned(packed, bits) == vals).all()


@settings(max_examples=100, deadline=None)
@given(BITS, st.integers(1, 64), st.integers(0, 2**32 - 1))
def test_roundtrip_signed(bits, groups, seed):
    rng = np.random.default_rng(seed)
    n = groups * packing.per_byte(bits)
    vals = rng.integers(-(1 << (bits - 1)), 1 << (bits - 1), n)
    packed = packing.pack_signed(vals, bits)
    assert (packing.unpack_signed(packed, bits) == vals).all()


@settings(max_examples=60, deadline=None)
@given(BITS, st.integers(1, 8), st.integers(0, 2**32 - 1))
def test_threshold_equals_affine(ybits, channels, seed):
    rng = packing.Xorshift(seed)
    phi_max = 1 << 14
    q = packing.random_params(rng, channels, ybits, phi_max, 64)
    nrng = np.random.default_rng(seed)
    phi = nrng.integers(-phi_max, phi_max, (32, channels))
    affine = q.quantize(phi)
    from compile.kernels import ref

    ladder = ref.quantize_thresholds(q, phi)
    assert (affine == ladder).all()


def test_thresholds_monotone():
    rng = packing.Xorshift(5)
    q = packing.random_params(rng, 4, 4, 10_000, 64)
    t = q.thresholds()
    assert (np.diff(t, axis=1) >= 0).all()


def test_random_params_no_i32_overflow():
    for ybits in (2, 4, 8):
        rng = packing.Xorshift(9)
        phi_max = 288 * 255 * 128  # the Reference Layer worst case
        q = packing.random_params(rng, 16, ybits, phi_max, 288)
        worst = phi_max * q.kappa + np.abs(q.lam)
        assert (worst < 2**31).all()
