"""L1 correctness: the Pallas kernel against the numpy oracle, across all
27 precision permutations and hypothesis-swept shapes."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import packing, qconv, ref

ALL_COMBOS = [(x, w, y) for x in (8, 4, 2) for w in (8, 4, 2) for y in (8, 4, 2)]


def run_both(spec: ref.ConvSpec, seed: int):
    x_packed, w_packed, q = ref.make_test_case(seed, spec)
    want = ref.conv2d(spec, x_packed, w_packed, q)
    thr, kl = qconv.quant_operands(q, spec.ybits)
    perx = packing.per_byte(spec.xbits)
    x_hwc = jnp.asarray(x_packed.reshape(spec.h, spec.w, spec.c // perx))
    w2d = jnp.asarray(w_packed.reshape(spec.cout, -1))
    got = qconv.qconv_layer(x_hwc, w2d, jnp.asarray(thr), jnp.asarray(kl), spec)
    return np.asarray(got).ravel(), want


@pytest.mark.parametrize("xbits,wbits,ybits", ALL_COMBOS)
def test_all_27_permutations_small(xbits, wbits, ybits):
    spec = ref.ConvSpec(5, 5, 8, 8, 3, 3, 1, 1, xbits, wbits, ybits)
    got, want = run_both(spec, seed=xbits * 100 + wbits * 10 + ybits)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("xbits,wbits,ybits", [(8, 8, 8), (4, 2, 4), (2, 4, 2)])
def test_reference_layer_combos(xbits, wbits, ybits):
    spec = ref.reference_layer(xbits, wbits, ybits)
    got, want = run_both(spec, seed=2020)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    st.sampled_from([8, 4, 2]),
    st.sampled_from([8, 4, 2]),
    st.sampled_from([8, 4, 2]),
    st.integers(3, 8),
    st.integers(3, 8),
    st.sampled_from([4, 8, 12]),
    st.sampled_from([4, 8]),
    st.sampled_from([(1, 1), (3, 1), (2, 0)]),  # (k, pad)
    st.sampled_from([1, 2]),
    st.integers(0, 2**31 - 1),
)
def test_random_shapes(xbits, wbits, ybits, h, w, c, cout, kpad, stride, seed):
    k, pad = kpad
    if h + 2 * pad < k or w + 2 * pad < k:
        return
    spec = ref.ConvSpec(h, w, c, cout, k, k, stride, pad, xbits, wbits, ybits)
    got, want = run_both(spec, seed)
    np.testing.assert_array_equal(got, want)


def test_unpack_sign_extension():
    packed = jnp.asarray(np.array([[0x8F]], dtype=np.uint8))
    out = np.asarray(qconv._unpack_signed(packed, 4))
    assert out.tolist() == [[-1, -8]]


def test_pack_unpack_jax_roundtrip():
    for bits in (2, 4, 8):
        vals = jnp.asarray(
            np.random.default_rng(1).integers(0, 1 << bits, (4, 8), dtype=np.int32)
        )
        packed = qconv._pack_unsigned(vals, bits)
        back = qconv._unpack_unsigned(packed, bits)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(vals))


def test_im2col_packed_matches_ref():
    spec = ref.ConvSpec(5, 6, 8, 4, 3, 3, 1, 1, 4, 8, 8)
    rng = packing.Xorshift(3)
    xv = packing.random_unsigned(rng, spec.h * spec.w * spec.c, spec.xbits)
    xp = packing.pack_unsigned(xv, spec.xbits)
    perx = packing.per_byte(spec.xbits)
    cols_packed = qconv.im2col_packed(
        jnp.asarray(xp.reshape(spec.h, spec.w, spec.c // perx)),
        spec.h, spec.w, spec.c, spec.kh, spec.kw, spec.stride, spec.pad, spec.xbits,
    )
    got = packing.unpack_unsigned(np.asarray(cols_packed), spec.xbits).reshape(
        spec.out_h * spec.out_w, spec.im2col_len
    )
    want = ref.im2col(spec, xv)
    np.testing.assert_array_equal(got, want)


def test_pick_tile_divides():
    assert qconv.pick_tile(256, 32) == 32
    assert qconv.pick_tile(20, 32) == 20
    assert qconv.pick_tile(30, 8) == 6
