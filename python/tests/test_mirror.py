"""Cross-language mirror fixtures: these exact values were produced by the
rust implementation (util::rng, util::check::fnv1a); if any of these fail,
the bit-exact weight materialization contract is broken."""

from compile.kernels import packing


def test_xorshift_known_vectors():
    r = packing.Xorshift(42)
    assert [r.next_u64() for _ in range(4)] == [
        6255019084209693600,
        14430073426741505498,
        14575455857230217846,
        17414512882241728735,
    ]


def test_below_known_vectors():
    r = packing.Xorshift(42)
    assert [r.below(1000) for _ in range(4)] == [339, 782, 790, 944]


def test_range_i32_known_vectors():
    r = packing.Xorshift(42)
    assert [r.range_i32(-8, 7) for _ in range(6)] == [-8, 2, -2, 7, -2, -5]


def test_zero_seed_remap():
    r = packing.Xorshift(0)
    assert r.next_u64() == 973819730272012410


def test_fnv1a_known_vectors():
    assert packing.fnv1a(b"conv0") == 7339226432074275701
    assert packing.fnv1a(b"head") == 760847531035462659
