# Convenience targets. The Rust build itself is plain `cargo build`.

ARTIFACTS ?= artifacts
SEED ?= 2020
TRACES ?= traces

.PHONY: all build test lint lint-json bench bench-hot trace artifacts doc clean

all: build

build:
	cargo build --release

test:
	cargo test -q

# pallas-lint: the determinism/invariant rules (D001-D011, see
# docs/STATIC_ANALYSIS.md) over rust/ + examples/. --deny exits non-zero
# on any active (non-allowed) diagnostic — the mode CI runs.
lint: build
	./target/release/pulpnn lint --deny

# Machine-readable sweep: JSONL (one object per diagnostic, suppressed
# ones included with "allowed":true) into $(ARTIFACTS)/pallas-lint.jsonl;
# CI uploads the same file as a build artifact.
lint-json: build
	mkdir -p $(ARTIFACTS)
	./target/release/pulpnn lint --format json > $(ARTIFACTS)/pallas-lint.jsonl

# Fast self-asserting bench pass (the same budget CI uses). des_hot,
# brownout_scale and fault_tolerance also emit BENCH_des_hot.json /
# BENCH_brownout.json / BENCH_fault.json into the repo root
# (pulpnn-bench-v1) — the machine-readable events/sec + work-counter
# perf trajectory and the brownout/fault-recovery serving timings.
bench:
	PULPNN_BENCH_BUDGET_MS=50 cargo bench --bench fleet_scale
	PULPNN_BENCH_BUDGET_MS=50 cargo bench --bench shard_scale
	PULPNN_BENCH_BUDGET_MS=50 cargo bench --bench sched_scale
	PULPNN_BENCH_BUDGET_MS=50 PULPNN_BENCH_JSON=. cargo bench --bench des_hot
	PULPNN_BENCH_BUDGET_MS=50 PULPNN_BENCH_JSON=. cargo bench --bench brownout_scale
	PULPNN_BENCH_BUDGET_MS=50 PULPNN_BENCH_JSON=. cargo bench --bench fault_tolerance

# The full-size des_hot run (>= 1.25M simulated requests) with the JSON
# trajectory — the events/sec baseline later perf PRs must beat.
bench-hot:
	PULPNN_BENCH_JSON=. cargo bench --bench des_hot

# Dump the canonical 10k-request mixed-tenant arrival trace (JSONL,
# replayable anywhere with `pulpnn serve --trace-in`).
trace: build
	mkdir -p $(TRACES)
	./target/release/pulpnn serve --devices 8 --requests 10000 --rate 2000 \
	  --tenants 4 --repeat-ratio 0.3 --deadline-ms 50 --seed $(SEED) \
	  --trace-out $(TRACES)/mixed_tenant_10k.jsonl

# AOT-export the artifacts the runtime/e2e paths load (python exporter;
# writes $(ARTIFACTS)/manifest.json plus per-artifact .hlo.txt/.bin files).
artifacts:
	cd python/compile && python3 aot.py --out-dir ../../$(ARTIFACTS) --seed $(SEED)

# The documentation gate CI enforces (missing docs in coordinator/energy
# are warnings promoted to errors here).
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
