# Convenience targets. The Rust build itself is plain `cargo build`.

ARTIFACTS ?= artifacts
SEED ?= 2020

.PHONY: all build test bench artifacts doc clean

all: build

build:
	cargo build --release

test:
	cargo test -q

# Fast self-asserting bench pass (the same budget CI uses).
bench:
	PULPNN_BENCH_BUDGET_MS=50 cargo bench --bench fleet_scale
	PULPNN_BENCH_BUDGET_MS=50 cargo bench --bench shard_scale

# AOT-export the artifacts the runtime/e2e paths load (python exporter;
# writes $(ARTIFACTS)/manifest.json plus per-artifact .hlo.txt/.bin files).
artifacts:
	cd python/compile && python3 aot.py --out-dir ../../$(ARTIFACTS) --seed $(SEED)

# The documentation gate CI enforces (missing docs in coordinator/energy
# are warnings promoted to errors here).
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
