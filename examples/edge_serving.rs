//! End-to-end serving driver (the repository's E2E validation):
//!
//!     make artifacts && cargo run --release --example edge_serving
//!
//! Loads the AOT'd demo CNN artifact, serves a batch of real inference
//! requests through the coordinator's queue on the artifact runtime
//! (native golden executor in this offline build; a PJRT client on
//! machines that have one) — measuring wall-clock latency/throughput —
//! and runs the same workload through the simulated GAP-8 edge fleet for
//! on-device latency/energy. Every response is verified bit-exact against
//! the rust golden model.

use pulpnn_mp::coordinator::{
    gap8_mixed_devices, merge_streams, server, ClosedLoopSource, Fleet, FleetConfig, Policy,
    QueueDiscipline, Server, ShardConfig, ShardedFleet, TraceSource, Workload,
    DEFAULT_WAKEUP_CYCLES,
};
use pulpnn_mp::energy::{DEFAULT_NET_SWITCH_CYCLES, GAP8_HP, GAP8_LP};
use pulpnn_mp::kernels::netrun::GapBackend;
use pulpnn_mp::qnn::network::demo_cnn;
use pulpnn_mp::qnn::tensor::QTensor;
use pulpnn_mp::runtime::{Manifest, Runtime};
use pulpnn_mp::util::rng::Rng;

const N_REQUESTS: usize = 64;
/// Requests 48..63 resubmit the inputs of requests 32..47, so the server's
/// result cache has something to hit.
const N_UNIQUE: usize = 48;

fn main() -> pulpnn_mp::util::error::Result<()> {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let artifact = manifest.find("demo_cnn_mixed").expect("demo artifact");
    let net = demo_cnn().materialize().unwrap();

    // --- phase 1: real inference through the serving queue ---
    let mut rt = Runtime::cpu()?;
    println!("runtime platform: {}", rt.platform());
    // pallas-lint: allow(D003, reason = "example reporting: compile time of the real artifact runtime")
    let t0 = std::time::Instant::now();
    let mut srv = Server::with_cache(&mut rt, artifact, 256)?;
    println!("compiled demo CNN in {:.0} ms", t0.elapsed().as_secs_f64() * 1e3);

    // generate request inputs (each a random packed image; the tail
    // resubmits earlier inputs to exercise the result cache) + goldens
    let inputs: Vec<(u64, QTensor)> = (0..N_REQUESTS as u64)
        .map(|id| {
            let unique = if (id as usize) < N_UNIQUE { id } else { id - 16 };
            let mut rng = Rng::new(1000 + unique);
            (id, QTensor::random(&mut rng, net.spec.input, net.spec.input_bits))
        })
        .collect();

    // pallas-lint: allow(D003, reason = "example reporting: wall-clock throughput of the real serving drain")
    let t0 = std::time::Instant::now();
    for (id, x) in &inputs {
        assert!(srv.submit(*id, x.data.clone()), "queue overflow");
    }
    let served = srv.drain()?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = server::stats(&served, wall);
    println!("\nserved {} requests through the artifact runtime:", stats.served);
    println!("  throughput : {:.1} req/s", stats.throughput_rps);
    println!("  mean exec  : {:.2} ms", stats.mean_exec_us / 1e3);
    println!("  p99 exec   : {:.2} ms", stats.p99_exec_us / 1e3);
    println!("  cache hits : {} (of {} duplicate inputs)", stats.cache_hits, N_REQUESTS - N_UNIQUE);
    assert_eq!(stats.cache_hits, N_REQUESTS - N_UNIQUE, "every duplicate input must hit");

    // verify every response against the rust golden model
    for ((id, x), s) in inputs.iter().zip(&served) {
        assert_eq!(*id, s.id);
        let want = net.forward_golden(x).logits.unwrap();
        let got = s.output.as_logits().expect("logits");
        assert_eq!(got, want.as_slice(), "request {id}: runtime != golden");
    }
    println!("  all {} responses bit-exact vs the golden model ✓", served.len());

    // --- phase 2: the same workload on the simulated edge fleet ---
    let mut rng = Rng::new(9);
    let x = QTensor::random(&mut rng, net.spec.input, net.spec.input_bits);
    let sim = GapBackend::default().run(&net, &x);
    println!(
        "\nsimulated GAP-8 (8 cores): {} cycles/inference = {:.2} ms LP / {:.2} ms HP",
        sim.total_cycles,
        GAP8_LP.time_ms(sim.total_cycles),
        GAP8_HP.time_ms(sim.total_cycles)
    );

    let nodes = gap8_mixed_devices(4, sim.total_cycles);
    let config = FleetConfig {
        queue_bound: 128,
        batch_max: 4,
        wakeup_cycles: DEFAULT_WAKEUP_CYCLES,
        ..FleetConfig::default()
    };
    let mut fleet = Fleet::with_config(nodes, Policy::EnergyAware, config);
    let reqs = Workload {
        rate_per_s: 150.0,
        deadline_us: Some(40_000.0),
        n_requests: 2000,
        seed: 7,
    }
    .generate();
    let report = fleet.run(&reqs);
    println!(
        "\nedge fleet (2x LP + 2x HP, energy-aware routing, 150 rps, 40 ms deadline,\n\
         queue bound 128, micro-batches of up to 4):"
    );
    println!("  throughput     : {:.1} req/s", report.throughput_rps);
    println!("  mean latency   : {:.2} ms", report.mean_latency_us / 1e3);
    println!("  p99 latency    : {:.2} ms", report.p99_latency_us / 1e3);
    println!(
        "  energy         : {:.2} mJ active + {:.2} mJ idle",
        report.active_energy_uj / 1e3,
        report.idle_energy_uj / 1e3
    );
    println!("  deadline misses: {}", report.deadline_misses);
    println!("  shed requests  : {}", report.shed);
    println!(
        "  activations    : {} ({:.2} requests/batch mean)",
        report.batches, report.mean_batch_size
    );
    println!("  per-device     : {:?}", report.per_device_served);

    // --- phase 3: the sharded multi-tenant tier with result caching ---
    // two tenant networks at 2x aggregate overload on 8 devices split
    // across 2 coordinator shards; each tenant's stream repeats half of
    // its inputs, so the front-tier cache absorbs a large slice of load
    let nodes = gap8_mixed_devices(8, sim.total_cycles);
    let capacity_rps: f64 = nodes.iter().map(|d| 1e6 / d.inference_us()).sum();
    let tier_fleet_config = FleetConfig {
        queue_bound: 32,
        batch_max: 4,
        wakeup_cycles: DEFAULT_WAKEUP_CYCLES,
        net_switch_cycles: DEFAULT_NET_SWITCH_CYCLES,
        ..FleetConfig::default()
    };
    let shard_config = ShardConfig {
        shards: 2,
        router_service_us: 100.0,
        tenancy_aware_routing: true,
        cache: true,
        cache_capacity: 1024,
        cache_quota_per_net: 768,
        ..ShardConfig::default()
    };
    let mut tier = ShardedFleet::new(nodes, Policy::TenancyAware, tier_fleet_config, shard_config);
    let tenants: Vec<_> = (0..2u32)
        .map(|t| {
            Workload {
                rate_per_s: capacity_rps, // 2 tenants at capacity each = 2x total
                deadline_us: None,
                n_requests: 2000,
                seed: 40 + t as u64,
            }
            .generate_with_repeats(t, 0.5)
        })
        .collect();
    let requests = merge_streams(&tenants);
    let tier_report = tier.run(&requests);
    tier_report.check_conservation(requests.len()).expect("request conservation");
    println!(
        "\nsharded tier (2 shards x 4 devices, 2 tenants pinned, 50% repeat inputs,\n\
         result cache on, 2x aggregate overload):"
    );
    println!(
        "  completed      : {} of {} ({} shed)",
        tier_report.total_completed,
        requests.len(),
        tier_report.total_shed
    );
    println!("  throughput     : {:.1} req/s", tier_report.throughput_rps);
    println!(
        "  result cache   : {}/{} hits ({:.0}%), ~{:.2} mJ device energy saved",
        tier_report.cache.hits,
        tier_report.cache.lookups,
        tier_report.cache.hit_rate * 100.0,
        tier_report.cache.energy_saved_uj / 1e3
    );
    println!(
        "  residency      : {} net-switches ({:.3} mJ)",
        tier_report.net_switches,
        tier_report.switch_energy_uj / 1e3
    );
    println!(
        "  energy         : {:.2} mJ active + {:.2} mJ idle",
        tier_report.active_energy_uj / 1e3,
        tier_report.idle_energy_uj / 1e3
    );
    println!(
        "  shards         : routed {:?}, utilization skew {:.3}",
        tier_report.per_shard_routed, tier_report.utilization_skew
    );
    println!(
        "  queue depth    : p50 {:.0} / p95 {:.0} / p99 {:.0}",
        tier_report.queue_depth_p50, tier_report.queue_depth_p95, tier_report.queue_depth_p99
    );
    assert!(tier_report.cache.hits > 0, "repeat inputs must produce cache hits");

    // the same tier, driven closed-loop: the unified event loop feeds
    // every completion (device, cache hit or join) back to the client
    // pool, so admission self-limits — bounded queues, zero shed
    let mut pool = ClosedLoopSource::new(16, 2_000.0, 2000, 52)
        .with_nets(2)
        .with_input_universe(64);
    let closed = tier.run_source(&mut pool).expect("closed loop drives the sharded tier");
    closed.check_conservation(pool.issued()).expect("closed-loop conservation");
    println!(
        "  closed loop    : 16 clients x 2 tenants, 64 shared inputs -> \
         {} of {} completed, {} shed, {} cache hits/joins",
        closed.total_completed,
        pool.issued(),
        closed.total_shed,
        closed.cache.hits
    );
    assert_eq!(closed.total_shed, 0, "closed-loop admission is self-limiting");

    // --- phase 4: the pluggable scheduling stack on an overload trace ---
    // bimodal deadlines (a latency-critical and a bulk class) at ~1.5x of
    // one LP device's capacity: EDF protects the tight class where FIFO
    // drowns it, and the trace round-trips through JSONL for replay
    let mut reqs = Workload {
        rate_per_s: 1.5e6 / GAP8_LP.time_ms(sim.total_cycles) / 1e3,
        deadline_us: None,
        n_requests: 600,
        seed: 11,
    }
    .generate();
    for r in &mut reqs {
        // the bulk-class deadline (30 s) is far beyond any backlog this
        // run can build, so only the tight class is ever at risk
        r.deadline_us = Some(if r.id % 2 == 0 { 15_000.0 } else { 3e7 });
    }
    let text = TraceSource::to_jsonl(&reqs);
    let mut trace = TraceSource::parse_jsonl(&text).expect("trace round-trips");
    let sched = |discipline: QueueDiscipline| {
        let devices = gap8_mixed_devices(1, sim.total_cycles);
        let config = FleetConfig { discipline, ..FleetConfig::default() };
        Fleet::with_config(devices, Policy::LeastLoaded, config).run(&reqs)
    };
    let fifo = sched(QueueDiscipline::Fifo);
    let edf = sched(QueueDiscipline::Edf);
    let replayed = Fleet::with_config(
        gap8_mixed_devices(1, sim.total_cycles),
        Policy::LeastLoaded,
        FleetConfig { discipline: QueueDiscipline::Edf, ..FleetConfig::default() },
    )
    .run_source(&mut trace);
    println!(
        "\nscheduling stack (1 LP device, 1.5x overload, 15 ms / 30 s bimodal deadlines):\n\
         \x20 FIFO deadline misses: {}\n\
         \x20 EDF  deadline misses: {}\n\
         \x20 EDF replayed from its JSONL trace: {} misses (bit-exact: {})",
        fifo.deadline_misses,
        edf.deadline_misses,
        replayed.deadline_misses,
        replayed.deadline_misses == edf.deadline_misses
            && replayed.throughput_rps == edf.throughput_rps
    );
    assert!(
        edf.deadline_misses <= fifo.deadline_misses,
        "EDF must not miss more deadlines than FIFO here"
    );
    assert_eq!(replayed.deadline_misses, edf.deadline_misses);
    Ok(())
}
