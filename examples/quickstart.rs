//! Quickstart: run one mixed-precision convolution on the simulated GAP-8
//! cluster and check it against the golden model.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 60-second tour of the library: build the paper's Reference
//! Layer at a mixed precision (4-bit ifmaps, 2-bit weights, 4-bit ofmaps),
//! run it on 1 and 8 cores, print MACs/cycle, latency and energy.

use pulpnn_mp::energy::{GAP8_HP, GAP8_LP};
use pulpnn_mp::kernels::{conv_parallel, ConvKernel, Engine, GAP8_TCDM_BANKS};
use pulpnn_mp::qnn::golden;
use pulpnn_mp::qnn::layer::ConvSpec;
use pulpnn_mp::qnn::tensor::{QTensor, QWeights};
use pulpnn_mp::qnn::types::{Bits, Precision};
use pulpnn_mp::util::rng::Rng;

fn main() {
    // 1. Declare a layer: the paper's Reference Layer at x4/w2/y4.
    let prec = Precision::new(Bits::B4, Bits::B2, Bits::B4);
    let spec = ConvSpec::reference_layer(prec);
    println!(
        "layer: {} ifmap -> {} ofmap, {}x{} filters, kernel {}",
        spec.input,
        spec.output(),
        spec.kh,
        spec.kw,
        prec.kernel_name()
    );

    // 2. Materialize packed tensors + quantization parameters.
    let mut rng = Rng::new(42);
    let x = QTensor::random(&mut rng, spec.input, prec.x);
    let w = QWeights::random(&mut rng, spec.cout, spec.kh, spec.kw, spec.input.c, prec.w);
    let q = spec.default_quant();
    println!(
        "packed footprints: ifmap {} B, weights {} B (vs {} B at int8)",
        x.packed_bytes(),
        w.packed_bytes(),
        w.elems()
    );

    // 3. Single-core run with phase breakdown.
    let kernel = ConvKernel::new(spec.clone(), &w, q.clone());
    let mut e = Engine::single_core();
    let (out1, stats) = kernel.run(&mut e, &x);
    println!("\nsingle core:");
    println!("  cycles        : {}", stats.cycles);
    println!("  MACs/cycle    : {:.3}", stats.macs_per_cycle());
    println!(
        "  phases        : im2col {} | matmul {} | qntpack {} | overhead {}",
        stats.phases.im2col, stats.phases.matmul, stats.phases.qntpack, stats.phases.overhead
    );

    // 4. Octa-core run.
    let run8 = conv_parallel(&kernel, &x, 8, GAP8_TCDM_BANKS);
    println!("\n8 cores:");
    println!("  cycles        : {}", run8.cycles);
    println!("  MACs/cycle    : {:.3}", run8.macs_per_cycle());
    println!("  speed-up      : {:.2}x", stats.cycles as f64 / run8.cycles as f64);
    println!(
        "  latency       : {:.3} ms (LP) / {:.3} ms (HP)",
        GAP8_LP.time_ms(run8.cycles),
        GAP8_HP.time_ms(run8.cycles)
    );
    println!(
        "  energy        : {:.1} uJ (LP) / {:.1} uJ (HP)",
        GAP8_LP.energy_uj(run8.cycles),
        GAP8_HP.energy_uj(run8.cycles)
    );

    // 5. Verify against the golden model.
    let want = golden::conv2d(&spec, &x, &w, &q);
    assert_eq!(out1.data, want.data, "single-core kernel != golden");
    assert_eq!(run8.out.data, want.data, "8-core kernel != golden");
    println!("\nboth runs match the golden reference bit-exactly ✓");
}
