//! MobileNetV1 on the edge: the paper's motivating case study.
//!
//!     cargo run --release --example mobilenet_edge
//!
//! Reproduces the §1 claim (via CMix-NN [1]): a mixed-precision
//! MobileNetV1 shrinks ~7x vs the int-32 baseline, and estimates full
//! network latency/energy on GAP-8 by combining the layer inventory with
//! the measured per-precision MACs/cycle of the simulated kernel library.

use pulpnn_mp::bench::figures::reference_case;
use pulpnn_mp::energy::{GAP8_HP, GAP8_LP};
use pulpnn_mp::kernels::{conv_parallel, GAP8_TCDM_BANKS};
use pulpnn_mp::qnn::footprint::*;
use pulpnn_mp::qnn::types::{Bits, Precision};
use pulpnn_mp::util::table::{f, Table};

/// Measure 8-core MACs/cycle for a (wbits, xbits) pair on the Reference
/// Layer — the per-precision throughput model for the estimate below.
fn macs_per_cycle(wbits: u32, xbits: u32) -> f64 {
    let prec = Precision::new(
        Bits::from_u32(xbits).unwrap(),
        Bits::from_u32(wbits).unwrap(),
        Bits::B8,
    );
    let (kernel, x) = reference_case(prec, 11);
    conv_parallel(&kernel, &x, 8, GAP8_TCDM_BANKS).macs_per_cycle()
}

fn main() {
    let inv = mobilenet_v1_inventory();
    let total_macs: u64 = inv.iter().map(|l| l.macs()).sum();
    println!(
        "MobileNetV1 1.0/224: {} layers, {:.1} M weights, {:.0} M MACs\n",
        inv.len(),
        inv.iter().map(|l| l.weight_elems()).sum::<usize>() as f64 / 1e6,
        total_macs as f64 / 1e6
    );

    // footprint table (the 7x claim)
    let mut t = Table::new(vec!["assignment", "weights [KiB]", "peak act [KiB]", "vs int-32"]);
    let base = footprint_report(&inv, Assignment::UniformBits(32));
    for (label, a) in [
        ("int-32 baseline", Assignment::UniformBits(32)),
        ("uniform INT8", Assignment::UniformBits(8)),
        ("uniform INT4", Assignment::UniformBits(4)),
        ("mixed (CMix-NN style)", Assignment::MixedCmix),
    ] {
        let r = footprint_report(&inv, a);
        t.row(vec![
            label.to_string(),
            f(r.weight_bytes as f64 / 1024.0, 0),
            f(r.peak_activation_bytes as f64 / 1024.0, 0),
            format!("{}x", f(base.weight_bytes as f64 / r.weight_bytes as f64, 1)),
        ]);
    }
    print!("{}", t.render());
    let mixed = footprint_report(&inv, Assignment::MixedCmix);
    let ratio = base.weight_bytes as f64 / mixed.weight_bytes as f64;
    println!("\nmixed-precision weight footprint reduction: {ratio:.1}x (paper: ~7x)\n");

    // latency/energy estimate on GAP-8 per assignment, from measured
    // kernel throughputs
    println!("estimated full-network inference on GAP-8 (8 cores):\n");
    let mut t = Table::new(vec![
        "assignment", "est. Mcycles", "latency LP [ms]", "latency HP [ms]", "energy LP [mJ]",
    ]);
    for (label, a) in [
        ("uniform INT8", Assignment::UniformBits(8)),
        ("uniform INT4", Assignment::UniformBits(4)),
        ("mixed (CMix-NN style)", Assignment::MixedCmix),
    ] {
        let bits = assign(&inv, a);
        let mut cycles = 0f64;
        for (l, (wb, ab)) in inv.iter().zip(&bits) {
            let mpc = macs_per_cycle((*wb).min(8), (*ab).min(8));
            cycles += l.macs() as f64 / mpc;
        }
        t.row(vec![
            label.to_string(),
            f(cycles / 1e6, 1),
            f(GAP8_LP.time_ms(cycles as u64), 1),
            f(GAP8_HP.time_ms(cycles as u64), 1),
            f(GAP8_LP.energy_uj(cycles as u64) / 1e3, 2),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nnote: INT4 weights trade ~2.5x kernel slow-down (Fig. 4) for 2x\n\
         footprint; the mixed assignment keeps throughput-critical layers fast."
    );
}
