//! Precision design-space explorer: for a user-specified layer shape,
//! sweep all 27 precision permutations and report the
//! footprint / throughput / energy Pareto view the paper's mixed-precision
//! argument rests on.
//!
//!     cargo run --release --example precision_explorer -- [H W Cin Cout K]

use pulpnn_mp::energy::GAP8_LP;
use pulpnn_mp::kernels::{conv_parallel, ConvKernel, GAP8_TCDM_BANKS};
use pulpnn_mp::qnn::layer::ConvSpec;
use pulpnn_mp::qnn::tensor::{QTensor, QWeights};
use pulpnn_mp::qnn::types::{Hwc, Precision};
use pulpnn_mp::util::rng::Rng;
use pulpnn_mp::util::table::{f, Table};

fn main() {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let (h, w, cin, cout, k) = match args.as_slice() {
        [h, w, cin, cout, k] => (*h, *w, *cin, *cout, *k),
        _ => (16, 16, 32, 64, 3), // the Reference Layer
    };
    println!("exploring {h}x{w}x{cin} -> {cout} channels, {k}x{k} filters\n");

    let mut t = Table::new(vec![
        "kernel", "w+act KiB", "8-core MACs/cyc", "latency LP [ms]", "energy LP [uJ]",
        "eff. [uJ/MMAC]",
    ]);
    let mut best_energy = f64::MAX;
    let mut best_name = String::new();
    for prec in Precision::all() {
        let spec = ConvSpec {
            name: format!("explore_{}", prec.kernel_name()),
            input: Hwc::new(h, w, cin),
            cout,
            kh: k,
            kw: k,
            stride: 1,
            pad: k / 2,
            prec,
        };
        if spec.validate().is_err() {
            continue;
        }
        let mut rng = Rng::new(5);
        let x = QTensor::random(&mut rng, spec.input, prec.x);
        let wq = QWeights::random(&mut rng, cout, k, k, cin, prec.w);
        let q = spec.default_quant();
        let kib = (wq.packed_bytes() + x.packed_bytes() + spec.output().packed_bytes(prec.y))
            as f64
            / 1024.0;
        let kernel = ConvKernel::new(spec.clone(), &wq, q);
        let run = conv_parallel(&kernel, &x, 8, GAP8_TCDM_BANKS);
        let uj = GAP8_LP.energy_uj(run.cycles);
        let eff = uj / (spec.macs() as f64 / 1e6);
        if uj < best_energy {
            best_energy = uj;
            best_name = prec.kernel_name();
        }
        t.row(vec![
            prec.kernel_name(),
            f(kib, 1),
            f(run.macs_per_cycle(), 2),
            f(GAP8_LP.time_ms(run.cycles), 3),
            f(uj, 1),
            f(eff, 2),
        ]);
    }
    print!("{}", t.render());
    println!("\nlowest-energy kernel: {best_name} ({best_energy:.1} uJ)");
    println!(
        "takeaway: 8-bit kernels minimize energy/inference; sub-byte kernels\n\
         minimize memory — the mixed-precision space trades between them."
    );
}
